"""Cross-request prefix cache (ISSUE 9 tentpole, DESIGN.md §10).

Three layers of coverage:

  * pool-level unit tests for the two `DynamicBlockGroupManager`
    primitives the cache is built on (`release_tail_group` refusal,
    `transfer_prefix_blocks` donation with tail retention, the
    refcounted-block free tripwire);
  * radix-tree unit + property tests against a *sentinel-pool* reference
    model — every physical block carries the token chunk its KV encodes,
    so "match is bit-exact" reduces to "node.key == phys[node.block]"
    under arbitrary insert/match/fork/evict/abort interleavings
    (hypothesis is dev-only: the property tests skip without it, the
    deterministic interleavings below always run);
  * real-engine acceptance tests: N users sharing a system prompt
    perform exactly ONE full-prefix prefill (asserted on the runner's
    prefill-token accounting) and the emitted token histories stay
    bit-identical to the cache-disabled baseline under storm
    preemption + swaps with the refcount sanitizer on every step.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:               # stub the decorators: defs still parse,
    class _NoStrategies:          # the property tests skip individually
        def __getattr__(self, name):
            return lambda *a, **k: None
    st = _NoStrategies()

    def given(*a, **k):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed; see requirements-dev.txt")(fn)

    def settings(*a, **k):
        return lambda fn: fn

from repro.core.block_group import (  # noqa: E402
    DynamicBlockGroupManager, OutOfBlocksError)
from repro.core.prefix_cache import PrefixCache  # noqa: E402

BS = 4


# ---------------------------------------------------------------------------
# pool primitives
# ---------------------------------------------------------------------------

def test_release_tail_group_refuses_refcounted():
    mgr = DynamicBlockGroupManager(8, BS)
    mgr.allocate_tokens(1, 2 * BS)
    blocks = mgr.request_block_ids(1)
    mgr.ref_block(blocks[-1])
    assert mgr.release_tail_group(1) is None     # a sharer still maps it
    mgr.unref_block(blocks[-1])
    freed = mgr.release_tail_group(1)
    assert freed is not None
    assert mgr.free_blocks() == 8
    assert mgr.release_tail_group(1) is None     # nothing left to release
    mgr.check_invariants()


def test_refcounted_blocks_never_reach_free_list():
    mgr = DynamicBlockGroupManager(8, BS)
    mgr.allocate_tokens(1, 2 * BS)
    mgr.ref_block(mgr.request_block_ids(1)[0])
    with pytest.raises(AssertionError):
        mgr.release_request(1)                   # tripwire, not silent free


def test_transfer_prefix_blocks_donation():
    mgr = DynamicBlockGroupManager(16, BS)
    mgr.allocate_tokens(1, 5 * BS)
    mgr.note_tokens(1, 5 * BS)
    table = mgr.request_block_ids(1)
    donated = mgr.transfer_prefix_blocks(1, [-9001, -9002, -9003])
    # physical blocks don't move: composed table is byte-identical
    assert donated == table[:3]
    assert mgr.request_block_ids(1) == table[3:]
    assert mgr.request_tokens(1) == 2 * BS
    for owner, b in zip([-9001, -9002, -9003], donated):
        assert mgr.request_block_ids(owner) == [b]
        assert mgr.request_tokens(owner) == BS
    mgr.check_invariants()
    # donated blocks release through the same tail API contamination uses
    assert mgr.release_tail_group(-9002) is not None
    mgr.check_invariants()


def test_transfer_keeps_unused_tail_with_donor():
    mgr = DynamicBlockGroupManager(16, BS)
    mgr.allocate_tokens(1, 3 * BS - 2)           # 3 used blocks, group of 4
    mgr.note_tokens(1, 3 * BS - 2)
    used = mgr.request_block_ids(1)
    assert len(used) == 3
    mgr.transfer_prefix_blocks(1, [-1, -2, -3])  # donate ALL used blocks
    # the unused group tail stays with the donor (still allocated, usable)
    assert mgr.request_block_ids(1) == []
    assert mgr.request_tokens(1) == 0
    mgr.check_invariants()
    before = mgr.free_blocks()
    mgr.allocate_tokens(1, 2)                    # grows into the kept tail
    assert mgr.free_blocks() == before
    mgr.check_invariants()


# ---------------------------------------------------------------------------
# radix tree units
# ---------------------------------------------------------------------------

def _fresh(n_blocks=32):
    mgr = DynamicBlockGroupManager(n_blocks, BS)
    return mgr, PrefixCache(mgr, BS)


def _prefill(mgr, rid, ids, shared=0):
    """Simulate the engine's private-suffix allocation for a prompt."""
    mgr.allocate_tokens(rid, len(ids) - shared)
    mgr.note_tokens(rid, len(ids) - shared)


def test_acquire_miss_insert_hit_roundtrip():
    mgr, cache = _fresh()
    ids = list(range(1, 14))                     # 13 tokens -> 3 cacheable
    assert cache.acquire(1, ids) == 0            # cold tree: miss
    _prefill(mgr, 1, ids)
    donated_from = mgr.request_block_ids(1)[:3]
    assert cache.insert(1, ids) == 3 * BS
    # a second identical prompt maps the full cacheable prefix
    shared = cache.acquire(2, ids)
    assert shared == 3 * BS
    assert cache.blocks_for(2) == donated_from   # same physical blocks
    assert cache.shared_tokens(2) == 3 * BS
    # both the donor and the sharer pin every node block
    for b in donated_from:
        assert mgr.block_refcount(b) == 2
    st = cache.stats()
    assert (st["hits"], st["misses"], st["tokens_saved"]) == (1, 1, 12)
    cache.release(1)
    cache.release(2)
    assert all(mgr.block_refcount(b) == 0 for b in donated_from)
    mgr.check_invariants()


def test_insert_chunk_keys_are_consecutive():
    """Regression (ISSUE 9): ``insert`` computed each node's chunk index
    from the mapped list WHILE appending to it, keying new nodes on
    chunks 0, 2, 4, … — a later prompt whose chunk-1 happened to equal
    the donor's chunk-2 would map the wrong KV block.  A fresh insert
    must be fully re-matchable, chunk by chunk."""
    mgr, cache = _fresh()
    ids = list(range(1, 18))                     # 17 tokens -> 4 cacheable
    _prefill(mgr, 1, ids)
    assert cache.insert(1, ids) == 4 * BS
    assert cache.match_tokens(ids) == 4 * BS
    path = cache._walk(ids, 4)
    assert [t for n in path for t in n.key] == ids[:4 * BS]


def test_last_prompt_block_stays_private():
    """COW by construction: the block holding the last prompt token is
    the first decode slot's block — it is never cacheable, so a sharer
    can never write a shared block."""
    mgr, cache = _fresh()
    ids = list(range(1, 1 + 2 * BS))             # exactly 2 full blocks
    _prefill(mgr, 1, ids)
    assert cache.insert(1, ids) == 1 * BS        # only block 0 donated
    assert cache.match_tokens(ids) == 1 * BS


def test_fork_divergence_creates_sibling():
    mgr, cache = _fresh()
    a = [1, 2, 3, 4, 5, 6, 7, 8, 9]              # 2 cacheable blocks
    b = [1, 2, 3, 4, 50, 60, 70, 80, 90]         # diverges in block 1
    _prefill(mgr, 1, a)
    cache.insert(1, a)
    shared = cache.acquire(2, b)
    assert shared == 1 * BS                      # block 0 shared only
    _prefill(mgr, 2, b, shared=shared)
    assert cache.insert(2, b) == 1 * BS          # sibling under block 0
    assert cache.n_nodes == 3
    assert cache.match_tokens(a) == 2 * BS
    assert cache.match_tokens(b) == 2 * BS
    mgr.check_invariants()


def test_concurrent_identical_insert_skips():
    """Two identical admissions both miss (tree cold), both prefill; the
    second ``insert`` would fork duplicate interior nodes — it must skip
    and keep its private blocks."""
    mgr, cache = _fresh()
    ids = list(range(1, 14))
    assert cache.acquire(1, ids) == 0
    assert cache.acquire(2, ids) == 0
    _prefill(mgr, 1, ids)
    _prefill(mgr, 2, ids)
    assert cache.insert(1, ids) == 3 * BS
    table2 = mgr.request_block_ids(2)
    assert cache.insert(2, ids) == 0             # deeper path exists: skip
    assert mgr.request_block_ids(2) == table2    # private blocks untouched
    assert cache.n_nodes == 3
    mgr.check_invariants()


def test_eviction_is_fairness_scored_and_leaf_only():
    mgr, cache = _fresh()
    a, b = [1, 2, 3, 4, 5], [9, 8, 7, 6, 5]      # one cacheable block each
    _prefill(mgr, 1, a)
    cache.insert(1, a, now_us=0.0, priority=0.1)
    _prefill(mgr, 2, b)
    cache.insert(2, b, now_us=0.0, priority=0.9)
    cache.release(1)
    cache.release(2)
    cache.acquire(3, b, now_us=50.0, priority=0.9)   # recent hot hit on b
    cache.release(3)
    # a: old, no hits, low historical priority -> worst score, goes first
    assert cache.evict(1, now_us=100.0) == 1
    assert cache.match_tokens(a) == 0
    assert cache.match_tokens(b) == 1 * BS
    mgr.check_invariants()


def test_eviction_refuses_mapped_leaves():
    mgr, cache = _fresh()
    ids = list(range(1, 14))
    _prefill(mgr, 1, ids)
    cache.insert(1, ids)                         # rid 1 still maps the path
    assert cache.evict(10) == 0                  # every leaf is refcounted
    cache.release(1)
    assert cache.evict(10) == 3                  # now the whole chain goes
    assert cache.n_nodes == 0
    mgr.release_request(1)
    assert mgr.free_blocks() == 32
    mgr.check_invariants()


# ---------------------------------------------------------------------------
# sentinel-pool reference model (S5)
# ---------------------------------------------------------------------------

class _SentinelModel:
    """Reference model: ``phys[block]`` is the token chunk whose KV the
    block holds.  The engine writes a block exactly once (its prefill),
    so if the tree's bookkeeping is right, every node's key must keep
    matching its block's sentinel forever — any aliasing, premature free
    or mis-keyed insert shows up as a sentinel mismatch."""

    def __init__(self, n_blocks=24):
        self.mgr = DynamicBlockGroupManager(n_blocks, BS)
        self.cache = PrefixCache(self.mgr, BS)
        self.phys = {}
        self.prompts = {}
        self.now = 0.0

    def _tick(self):
        self.now += 1.0
        return self.now

    def _drop_freed(self):
        for start, length in self.mgr.free.items():
            for blk in range(start, start + length):
                self.phys.pop(blk, None)

    def admit(self, rid, ids, priority=0.5):
        if rid in self.prompts:
            return False
        shared = self.cache.acquire(rid, ids, now_us=self._tick(),
                                    priority=priority)
        need = len(ids) - shared
        try:
            self.mgr.allocate_tokens(rid, need)
        except OutOfBlocksError:
            # engine behaviour: evict cache leaves first, retry once
            self.cache.evict(-(-need // BS), now_us=self.now)
            self._drop_freed()
            try:
                self.mgr.allocate_tokens(rid, need)
            except OutOfBlocksError:
                self.cache.release(rid)
                return False
        self.mgr.note_tokens(rid, need)
        # prefill writes ONLY the private suffix blocks
        table = (self.cache.blocks_for(rid)
                 + self.mgr.request_block_ids(rid))
        for j, blk in enumerate(table):
            chunk = tuple(ids[j * BS:(j + 1) * BS])
            if j * BS >= shared:
                self.phys[blk] = chunk
            else:                           # shared block: never rewritten
                assert self.phys.get(blk) == chunk
        self.prompts[rid] = ids
        return True

    def donate(self, rid):
        if rid not in self.prompts:
            return False
        self.cache.insert(rid, self.prompts[rid], now_us=self._tick(),
                          priority=0.5)
        return True

    def finish(self, rid):
        if rid not in self.prompts:
            return False
        self.cache.release(rid)
        self.mgr.release_request(rid)
        self._drop_freed()
        del self.prompts[rid]
        return True

    def evict(self, n):
        self.cache.evict(n, now_us=self._tick())
        self._drop_freed()

    def check(self):
        self.mgr.check_invariants()
        node_blocks = set()
        want_refs = {}
        for rid in self.prompts:
            for n in self.cache.mappings().get(rid, []):
                want_refs[n.block] = want_refs.get(n.block, 0) + 1
        for node in self.cache.iter_nodes():
            node_blocks.add(node.block)
            # bit-exactness: the block still holds the chunk its key says
            assert self.phys.get(node.block) == node.key, \
                (node.key, self.phys.get(node.block))
            assert self.mgr.block_refcount(node.block) == \
                want_refs.get(node.block, 0)
        for start, length in self.mgr.free.items():
            assert not (node_blocks & set(range(start, start + length))), \
                "cached block on the free list"
        for rid, ids in self.prompts.items():
            maps = self.cache.mappings().get(rid, [])
            flat = [t for n in maps for t in n.key]
            assert flat == list(ids[:len(maps) * BS])
            table = (self.cache.blocks_for(rid)
                     + self.mgr.request_block_ids(rid))
            assert len(table) == len(set(table)), "aliased block table"
            # private suffix blocks are never simultaneously tree nodes
            assert not (set(self.mgr.request_block_ids(rid)) & node_blocks)


_PREFIXES = [list(range(100, 112)),              # 3 full blocks
             list(range(200, 208)),              # 2 full blocks
             list(range(100, 108))]              # prefix of the first


def _prompt(p, rid, extra):
    return _PREFIXES[p % len(_PREFIXES)] + \
        [1000 * (rid + 1) + i for i in range(extra % 7)]


def test_interleaved_share_fork_evict_deterministic():
    m = _SentinelModel()
    p1 = _prompt(0, 1, 5)
    assert m.admit(1, p1)
    m.donate(1)
    m.check()
    p2 = _prompt(0, 2, 6)                        # same 12-token prefix
    assert m.admit(2, p2)
    assert m.cache.shared_tokens(2) == 12
    m.donate(2)                                  # forks below the share
    m.check()
    p3 = _prompt(1, 3, 4)                        # different system prompt
    assert m.admit(3, p3)
    assert m.cache.shared_tokens(3) == 0
    m.donate(3)
    m.check()
    m.finish(1)
    m.check()                                    # rid 2 keeps the prefix hot
    m.evict(100)                                 # only unmapped leaves go
    assert m.cache.match_tokens(p2) >= 12
    m.check()
    m.finish(2)
    m.finish(3)
    m.evict(100)
    assert m.cache.n_nodes == 0
    assert m.mgr.free_blocks() == 24
    m.check()


def test_pressure_eviction_never_frees_mapped_blocks():
    m = _SentinelModel(n_blocks=8)
    assert m.admit(1, _prompt(0, 1, 5))          # 12 shared-able + tail
    m.donate(1)
    m.check()
    # pool nearly full: the next distinct admission must evict, but rid 1
    # still maps the tree — admission fails instead of corrupting it
    assert not m.admit(2, _prompt(1, 2, 6) + list(range(300, 314)))
    m.check()
    m.finish(1)
    m.check()
    # with the mapping gone the same admission evicts the old prefix
    assert m.admit(2, _prompt(1, 2, 6) + list(range(300, 314)))
    m.check()


@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3),     # op
                          st.integers(0, 5),     # rid
                          st.integers(0, 5),     # prefix choice / evict n
                          st.integers(0, 6)),    # suffix length
                min_size=1, max_size=40))
def test_prefix_tree_interleaving_property(ops):
    """Property (S5): under ANY interleaving of admit/donate/finish/evict
    the tree never frees a refcounted block, never aliases a private
    suffix with a cached block, and every mapping stays bit-exact against
    the sentinel pool."""
    m = _SentinelModel(n_blocks=16)
    for op, rid, p, extra in ops:
        if op == 0:
            m.admit(rid, _prompt(p, rid, extra))
        elif op == 1:
            m.donate(rid)
        elif op == 2:
            m.finish(rid)
        else:
            m.evict(p)
        m.check()
    for rid in list(m.prompts):
        m.finish(rid)
    m.evict(100)
    m.check()
    assert m.mgr.free_blocks() == 16


# ---------------------------------------------------------------------------
# real-engine acceptance (ISSUE 9 criteria)
#
# Each workload runs in a FRESH SUBPROCESS — same rationale as
# tests/test_system.py: jaxlib's native backend_compile segfaults once a
# single full-suite process has accumulated enough compiled executables,
# and these tests compile several real-engine variants each.  Every
# child re-derives the model/prompts from fixed seeds and prints one
# JSON line; behavioural asserts run in the child so the parent sees the
# full failure text.
# ---------------------------------------------------------------------------

import os       # noqa: E402
import subprocess  # noqa: E402
import sys      # noqa: E402

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_ENGINE_PRELUDE = """
import json

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import EngineConfig, SamplingParams, ServingEngine
from repro.data.priority import PriorityTrace
from repro.models import transformer as T

cfg_m = get_smoke_config("qwen2-1.5b")
params = T.init_params(cfg_m, jax.random.PRNGKey(0))
model = {"cfg": cfg_m, "params": params}


def shared_prompts(n_req, prefix_len=49):
    rng = np.random.RandomState(7)
    sys_prefix = rng.randint(1, cfg_m.vocab_size, prefix_len).tolist()
    return [sys_prefix + rng.randint(1, cfg_m.vocab_size, 5 + 3 * i).tolist()
            for i in range(n_req)]


def run_shared(prompts, prefix_cache, num_gpu_blocks=64, max_tokens=8):
    cfg = EngineConfig(mode="real", num_gpu_blocks=num_gpu_blocks,
                       num_cpu_blocks=256, max_running=len(prompts),
                       max_batch=4, prefix_cache=prefix_cache,
                       check_invariants_every=1).with_policy("fastswitch")
    eng = ServingEngine(cfg, trace=PriorityTrace(), model_bundle=model,
                        stream_tokens=True)
    hists = {}

    def drain(budget):
        n = 0
        while eng.has_work() and n < budget:
            for out in eng.step():
                if out.token_ids:
                    hists.setdefault(out.handle, []).extend(out.token_ids)
            n += 1

    # the leader's prefill completes (and donates) before the sharers
    # arrive — the staggering a live arrival process produces
    eng.add_request(list(prompts[0]), SamplingParams(max_tokens=max_tokens),
                    handle=0)
    drain(2)
    for h, toks in enumerate(prompts[1:], start=1):
        eng.add_request(list(toks), SamplingParams(max_tokens=max_tokens),
                        handle=h)
    drain(5000)
    assert not eng.has_work()
    stats = {"prefill_tokens": eng.runner.stats.prefill_tokens,
             "metrics": eng.metrics,
             "prefix": eng.prefix.stats() if eng.prefix else {}}
    eng.shutdown()
    return hists, stats
"""


def _run_engine_child(code, timeout=900):
    env = {**os.environ, "PYTHONPATH": _SRC}
    r = subprocess.run([sys.executable, "-c", _ENGINE_PRELUDE + code],
                       env=env, capture_output=True, text=True,
                       timeout=timeout)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    import json
    return json.loads(r.stdout.splitlines()[-1])


def test_n_sharers_single_full_prefill():
    """Acceptance: N users sharing a system prompt perform exactly ONE
    full-prefix prefill.  The runner's prefill-token accounting must show
    the leader forwarding its whole prompt and every sharer forwarding
    ONLY its private suffix past the block-aligned shared prefix."""
    out = _run_engine_child("""
prompts = shared_prompts(n_req=4)
hists, s = run_shared(prompts, prefix_cache=True)
shared = (49 // 16) * 16                     # block-aligned prefix
expected = len(prompts[0]) + sum(len(p) - shared for p in prompts[1:])
assert s["prefill_tokens"] == expected, (s["prefill_tokens"], expected)
assert s["prefix"]["hits"] == len(prompts) - 1
assert s["metrics"].prefix_tokens_saved == (len(prompts) - 1) * shared
assert s["metrics"].invariant_checks > 0
assert len(hists) == len(prompts)
print(json.dumps({"prefill_tokens": s["prefill_tokens"],
                  "expected": expected,
                  "hits": s["prefix"]["hits"]}))
""")
    assert out["prefill_tokens"] == out["expected"]
    assert out["hits"] == 3


def test_storm_bit_exact_vs_cache_disabled():
    """Acceptance: under storm preemption + swaps (tight pool) the
    cache-on token histories are bit-exact against the cache-disabled
    baseline, with the refcount sanitizer (C1/C2) running every step."""
    out = _run_engine_child("""
prompts = shared_prompts(n_req=4)
h_off, s_off = run_shared(prompts, prefix_cache=False,
                          num_gpu_blocks=22, max_tokens=10)
h_on, s_on = run_shared(prompts, prefix_cache=True,
                        num_gpu_blocks=22, max_tokens=10)
assert h_on == h_off, "prefix cache changed the token histories"
assert all(len(h) == 10 for h in h_on.values())
# the pool was actually under storm pressure in the cache-on run
assert s_on["metrics"].preemptions > 0
assert s_on["metrics"].swap_out_count > 0
assert s_on["metrics"].invariant_checks > 0
assert s_on["prefill_tokens"] < s_off["prefill_tokens"]
print(json.dumps({"bit_exact": h_on == h_off,
                  "preemptions": s_on["metrics"].preemptions,
                  "pt_on": s_on["prefill_tokens"],
                  "pt_off": s_off["prefill_tokens"]}))
""")
    assert out["bit_exact"]
    assert out["preemptions"] > 0
    assert out["pt_on"] < out["pt_off"]


def test_engine_evicts_cache_before_preempting():
    """Block pressure reclaims unmapped cached leaves BEFORE preempting
    live requests: after the sharers finish, a new distinct prompt that
    doesn't fit alongside the pinned tree must trigger prefix evictions
    and still complete."""
    out = _run_engine_child("""
prompts = shared_prompts(n_req=2)
cfg = EngineConfig(mode="real", num_gpu_blocks=12, num_cpu_blocks=256,
                   max_running=2, max_batch=2, prefix_cache=True,
                   check_invariants_every=1).with_policy("fastswitch")
eng = ServingEngine(cfg, trace=PriorityTrace(), model_bundle=model,
                    stream_tokens=True)
eng.add_request(list(prompts[0]), SamplingParams(max_tokens=4), handle=0)
while eng.has_work():
    eng.step()
eng.add_request(list(prompts[1]), SamplingParams(max_tokens=4), handle=1)
while eng.has_work():
    eng.step()
assert eng.prefix.stats()["hits"] == 1       # the tree is populated
rng = np.random.RandomState(99)
# 150 tokens -> 10 blocks: more than the 9 left beside the 3-block
# pinned tree, so admission must reclaim cached leaves
big = rng.randint(1, cfg_m.vocab_size, 150).tolist()
eng.add_request(big, SamplingParams(max_tokens=4), handle=2)
done = False
while eng.has_work():
    for out in eng.step():
        if out.handle == 2 and out.finished:
            done = True
assert done
assert eng.metrics.prefix_evictions > 0
assert eng.metrics.invariant_checks > 0
evictions = eng.metrics.prefix_evictions
eng.shutdown()
print(json.dumps({"evictions": evictions}))
""")
    assert out["evictions"] > 0
