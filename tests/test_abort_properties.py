"""Cancellation correctness (ISSUE 5): ``abort()`` at any point in any
lifecycle state never leaks GPU/CPU blocks, never strands a swap task,
and leaves decode-runner rows clean (trash-sentinel block table).

Two layers:
  * a deterministic per-state unit matrix — one scenario per lifecycle
    state (WAITING, RUNNING, SWAPPED, SWAPPING_IN, mid-chunked-prefill,
    recompute-WAITING-resume, FINISHED/retained), sim + real;
  * a hypothesis property — random conversations, random priority storm,
    random abort schedule, across policies — end state must be fully
    reclaimed.
"""
import numpy as np
import pytest

from repro.core import (EngineConfig, SamplingParams, ServingEngine,
                        SLOSpec)
from repro.core.scheduler import ReqState
from repro.data.priority import PriorityTrace

# the deterministic per-state matrix runs everywhere; only the random
# schedule property needs hypothesis (installed via requirements-dev.txt)
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                 # pragma: no cover
    HAVE_HYPOTHESIS = False


def _engine(policy="fastswitch", **kw):
    trace = kw.pop("trace", None) or PriorityTrace("random", 1e-9, seed=0)
    defaults = dict(mode="sim", num_gpu_blocks=64, num_cpu_blocks=256,
                    block_size=16, max_running=8)
    defaults.update(kw)
    return ServingEngine(EngineConfig(**defaults).with_policy(policy),
                         trace=trace)


def _assert_request_gone(eng, h):
    assert h not in eng.sched.requests
    assert h not in eng.parked
    for q in (eng.sched.waiting, eng.sched.running, eng.sched.swapped,
              eng.sched.swapping_in):
        assert h not in q
    assert eng.gpu_mgr.request_block_ids(h) == []
    assert eng.reuse.mgr.request_block_ids(h) == []
    assert eng.reuse.valid_tokens(h) == 0
    assert all(t.req_id != h for t in eng.swap.ongoing_swap_in), \
        "stranded swap-in task"
    eng.gpu_mgr.check_invariants()
    eng.reuse.mgr.check_invariants()


def _assert_fully_reclaimed(eng):
    """With no live or retained requests, every block is free and every
    swap task retired."""
    # in-flight async swap-outs retire on their own timeline; drain them
    eng.clock.advance(1e9)
    eng.swap.synchronize(eng.clock, list(eng.swap.ongoing_swap_in)
                         + list(eng.swap.ongoing_swap_out))
    eng.swap.poll_completed(eng.clock)
    assert eng.gpu_mgr.free_blocks() == eng.gpu_mgr.num_blocks, \
        "leaked GPU blocks"
    assert eng.reuse.mgr.free_blocks() == eng.reuse.mgr.num_blocks, \
        "leaked CPU blocks"
    assert not eng.swap.ongoing_swap_in and not eng.swap.ongoing_swap_out, \
        "stranded swap task"
    eng.gpu_mgr.check_invariants()
    eng.reuse.mgr.check_invariants()


# ---------------------------------------------------------------------------
# deterministic per-state matrix (sim)
# ---------------------------------------------------------------------------


def test_abort_waiting():
    eng = _engine()
    h = eng.add_request(8, SamplingParams(max_tokens=4))
    assert eng._req(h).state == ReqState.WAITING
    assert eng.abort(h) is True
    _assert_request_gone(eng, h)
    _assert_fully_reclaimed(eng)
    eng.shutdown()


def test_abort_running():
    eng = _engine()
    h = eng.add_request(8, SamplingParams(max_tokens=40))
    eng.step()
    assert eng._req(h).state == ReqState.RUNNING
    assert eng.abort(h) is True
    _assert_request_gone(eng, h)
    _assert_fully_reclaimed(eng)
    eng.shutdown()


def test_abort_swapped():
    eng = _engine()
    h = eng.add_request(8, SamplingParams(max_tokens=40))
    eng.step()
    eng._preempt(h)
    assert eng._req(h).state == ReqState.SWAPPED
    assert eng.reuse.valid_tokens(h) > 0     # CPU copy exists pre-abort
    assert eng.abort(h) is True
    _assert_request_gone(eng, h)
    _assert_fully_reclaimed(eng)
    eng.shutdown()


def test_abort_swapping_in_mid_flight():
    eng = _engine()
    eng.swap.adaptive = False        # force async swaps
    h = eng.add_request(8, SamplingParams(max_tokens=40))
    eng.step()
    eng._preempt(h)
    assert eng._swap_in(h) is False  # async: in flight
    assert eng._req(h).state == ReqState.SWAPPING_IN
    assert any(t.req_id == h for t in eng.swap.ongoing_swap_in)
    assert eng.abort(h) is True
    _assert_request_gone(eng, h)
    _assert_fully_reclaimed(eng)
    eng.shutdown()


def test_abort_mid_chunked_prefill_sim():
    eng = _engine("fastswitch+chunked", num_gpu_blocks=128)
    h = eng.add_request(600, SamplingParams(max_tokens=4))
    eng.step()
    req = eng._req(h)
    assert req.state == ReqState.RUNNING and req.prefill_remaining > 0, \
        "scenario never entered chunked prefill"
    assert eng.abort(h) is True
    _assert_request_gone(eng, h)
    _assert_fully_reclaimed(eng)
    eng.shutdown()


def test_abort_mid_chunked_resume_recompute_sim():
    """Recompute preemption of a long request resumes through the
    chunked state machine (``prefill_is_resume``); aborting MID-resume
    must reclaim everything like any other state."""
    from dataclasses import replace

    from repro.core.policies import POLICIES
    pol = replace(POLICIES["vllm-recompute"], chunked_prefill_tokens=16)
    eng = ServingEngine(
        EngineConfig(mode="sim", num_gpu_blocks=64, num_cpu_blocks=256,
                     block_size=16, max_running=8, policy=pol),
        trace=PriorityTrace("random", 1e-9, seed=0))
    h = eng.add_request(60, SamplingParams(max_tokens=40))
    for _ in range(8):                 # finish the chunked fresh prefill
        eng.step()
    req = eng._req(h)
    assert req.prefill_remaining == 0 and req.generated > 0
    eng._preempt(h)
    assert req.state == ReqState.WAITING and req.resume_tokens > 16
    eng.step()                         # re-admit -> chunked resume opens
    assert req.prefill_remaining > 0 and req.prefill_is_resume, \
        "resume did not enter the chunked state machine"
    assert eng.abort(h) is True
    _assert_request_gone(eng, h)
    _assert_fully_reclaimed(eng)
    eng.shutdown()


def test_abort_recompute_waiting_resume():
    eng = _engine("vllm-recompute")
    h = eng.add_request(8, SamplingParams(max_tokens=40))
    eng.step()
    eng._preempt(h)
    req = eng._req(h)
    assert req.state == ReqState.WAITING and req.resume_tokens > 0
    assert eng.abort(h) is True
    _assert_request_gone(eng, h)
    _assert_fully_reclaimed(eng)
    eng.shutdown()


def test_abort_finished_retained_session():
    eng = _engine()
    h = eng.add_request(8, SamplingParams(max_tokens=3), retain_kv=True)
    while eng.has_work():
        eng.step()
    assert h in eng.parked and eng.reuse.valid_tokens(h) > 0
    assert eng.abort(h) is True
    _assert_request_gone(eng, h)
    _assert_fully_reclaimed(eng)
    eng.shutdown()


def test_abort_unknown_handle_is_noop():
    eng = _engine()
    assert eng.abort(999) is False
    eng.shutdown()


def test_abort_emits_output_and_event():
    eng = _engine()
    h = eng.add_request(8, SamplingParams(max_tokens=40),
                        slo=SLOSpec(ttft_ms=1e6))
    eng.step()
    eng.abort(h)
    outs = eng.step()        # the abort's output rides the next step
    fin = [o for o in outs if o.handle == h and o.finished]
    assert len(fin) == 1 and fin[0].finish_reason == "abort"
    assert [e.kind for e in eng.events if e.handle == h][-1] == "abort"
    # the partial turn still contributed an SLO attainment record
    assert any(s.handle == h and s.finish_reason == "abort"
               for s in eng.metrics.request_stats)
    assert eng.metrics.aborted == 1
    eng.shutdown()


# ---------------------------------------------------------------------------
# deterministic per-state matrix (real mode: runner-row sentinel checks)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_model():
    import jax

    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("qwen2-1.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return {"cfg": cfg, "params": params}


def _real_engine(tiny_model, policy="fastswitch", **kw):
    defaults = dict(mode="real", num_gpu_blocks=64, num_cpu_blocks=256,
                    block_size=16, max_running=4, max_batch=4)
    defaults.update(kw)
    return ServingEngine(EngineConfig(**defaults).with_policy(policy),
                         trace=PriorityTrace("random", 1e-9, seed=0),
                         model_bundle=tiny_model)


def _ids(n, vocab, seed=0):
    return np.random.RandomState(seed).randint(1, vocab, size=n).tolist()


def _assert_runner_row_clean(eng, h, row):
    """Sentinel check: the freed row's block table points only at the
    trash block, its context is zeroed and it is masked inactive."""
    assert h not in eng.runner._rows
    bt = np.asarray(eng.runner._bt)
    assert np.all(bt[row] == eng._trash_block), \
        f"freed row {row} still maps real blocks: {bt[row]}"
    assert int(np.asarray(eng.runner._ctx)[row]) == 0
    assert not bool(np.asarray(eng.runner._active)[row])


def test_abort_running_real_frees_runner_row(tiny_model):
    vocab = tiny_model["cfg"].vocab_size
    eng = _real_engine(tiny_model)
    h1 = eng.add_request(_ids(10, vocab, 1), SamplingParams(max_tokens=30))
    h2 = eng.add_request(_ids(10, vocab, 2), SamplingParams(max_tokens=30))
    for _ in range(4):
        eng.step()
    assert eng._req(h1).state == ReqState.RUNNING
    row = eng.runner._rows[h1]
    assert eng.abort(h1) is True
    _assert_request_gone(eng, h1)
    _assert_runner_row_clean(eng, h1, row)
    # the surviving request keeps decoding to completion
    while eng.has_work():
        eng.step()
    assert eng.metrics.total_tokens >= 30
    _assert_fully_reclaimed(eng)
    eng.shutdown()


def test_abort_mid_chunked_prefill_real(tiny_model):
    from dataclasses import replace

    from repro.core.policies import POLICIES
    vocab = tiny_model["cfg"].vocab_size
    pol = replace(POLICIES["fastswitch"], chunked_prefill_tokens=16)
    eng = ServingEngine(
        EngineConfig(mode="real", num_gpu_blocks=64, num_cpu_blocks=256,
                     block_size=16, max_running=4, max_batch=4, policy=pol),
        trace=PriorityTrace("random", 1e-9, seed=0),
        model_bundle=tiny_model)
    h = eng.add_request(_ids(80, vocab, 3), SamplingParams(max_tokens=4))
    eng.step()
    req = eng._req(h)
    assert req.prefill_remaining > 0, "never entered chunked prefill"
    assert h in eng.runner._prefills
    assert eng.abort(h) is True
    assert h not in eng.runner._prefills, "stranded prefill carry"
    _assert_request_gone(eng, h)
    _assert_fully_reclaimed(eng)
    eng.shutdown()


def test_abort_swapping_real_mid_swap_chunks(tiny_model):
    """Abort while the request's staged swap-in chunk tasks are still in
    flight: chunks retire, blocks free, and a NEW request can
    immediately claim the pool without corruption."""
    vocab = tiny_model["cfg"].vocab_size
    eng = _real_engine(tiny_model, swap_chunk_blocks=1)
    eng.swap.adaptive = False                  # force async
    h = eng.add_request(_ids(40, vocab, 4), SamplingParams(max_tokens=30))
    for _ in range(3):
        eng.step()
    eng._preempt(h)
    assert eng._swap_in(h) is False
    assert any(t.req_id == h for t in eng.swap.ongoing_swap_in)
    assert eng.abort(h) is True
    _assert_request_gone(eng, h)
    # fresh request takes over the freed pool and runs clean
    h2 = eng.add_request(_ids(12, vocab, 5), SamplingParams(max_tokens=6))
    while eng.has_work():
        eng.step()
    assert eng._token_hist_by_conv[h2][-6:], "successor never decoded"
    _assert_fully_reclaimed(eng)
    eng.shutdown()


# ---------------------------------------------------------------------------
# hypothesis: random abort schedule across policies and storms
# ---------------------------------------------------------------------------


def _run_random_abort_schedule(seed, policy, n_req, storm_freq,
                               n_aborts, abort_iters):
    """Abort random requests at random iterations under a random
    priority storm: whatever lifecycle state each abort lands in, the
    end state is fully reclaimed (no block leaks, no stranded tasks,
    clean pool-manager invariants)."""
    rng = np.random.RandomState(seed)
    eng = _engine(policy, num_gpu_blocks=16, num_cpu_blocks=64,
                  trace=PriorityTrace("random", storm_freq, seed=seed))
    handles = []
    for i in range(n_req):
        handles.append(eng.add_request(
            int(rng.randint(4, 80)),
            SamplingParams(max_tokens=int(rng.randint(1, 30))),
            retain_kv=bool(rng.randint(0, 2))))
    abort_iters = sorted(abort_iters)
    to_abort = list(rng.permutation(handles)[:n_aborts])
    it = 0
    while (eng.has_work() or eng.parked) and it < 5000:
        while abort_iters and abort_iters[0] <= it and to_abort:
            abort_iters.pop(0)
            eng.abort(int(to_abort.pop()))
        if eng.has_work():
            eng.step()
        else:       # only parked sessions left: release them
            for h in list(eng.parked):
                eng.release_session(h)
        it += 1
    assert it < 5000, "engine failed to drain"
    for h in handles:
        _assert_request_gone(eng, h)
    _assert_fully_reclaimed(eng)
    eng.shutdown()


@pytest.mark.parametrize("seed,policy,storm_freq", [
    (0, "fastswitch", 0.5),
    (1, "fastswitch+chunked", 0.5),
    (2, "vllm-recompute", 0.5),
    (3, "vllm", 1e-9),
])
def test_abort_schedule_fixed_seeds(seed, policy, storm_freq):
    """Deterministic instances of the random-schedule property (runs
    even without hypothesis installed)."""
    _run_random_abort_schedule(seed, policy, n_req=4,
                               storm_freq=storm_freq, n_aborts=2,
                               abort_iters=[1, 7])


if HAVE_HYPOTHESIS:
    def _property(seed, policy, n_req, storm_freq, data):
        n_aborts = data.draw(st.integers(1, n_req), label="n_aborts")
        abort_iters = data.draw(
            st.lists(st.integers(0, 40), min_size=n_aborts,
                     max_size=n_aborts), label="abort_iters")
        _run_random_abort_schedule(seed, policy, n_req, storm_freq,
                                   n_aborts, abort_iters)

    test_abort_any_state_never_leaks = settings(
        max_examples=25, deadline=None)(given(
            seed=st.integers(0, 2 ** 20),
            policy=st.sampled_from(["fastswitch", "fastswitch+chunked",
                                    "vllm", "vllm-recompute"]),
            n_req=st.integers(2, 6),
            storm_freq=st.sampled_from([1e-9, 0.5]),
            data=st.data(),
        )(_property))
else:                                               # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_abort_any_state_never_leaks():
        pass
