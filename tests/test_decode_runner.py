"""DecodeRunner: shape bucketing, incremental block-table updates,
pool-donation safety across swap round-trips, deferred token sync."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache.paged import PagedPools, PoolSpec
from repro.configs import get_smoke_config
from repro.core.decode_runner import (DecodeRequestView, DecodeRunner,
                                      next_pow2)
from repro.kernels.paged_attention import paged_attention
from repro.kernels.ref import paged_attention_ref
from repro.models import transformer as T
from repro.models.paged import paged_decode_step

BS = 4                       # tiny pages so boundaries come fast


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("qwen2-1.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mk_pool(cfg, nb):
    return jnp.zeros((cfg.n_layers, 2, nb, BS, cfg.n_kv_heads,
                      cfg.resolved_head_dim), jnp.bfloat16)


def test_next_pow2():
    assert [next_pow2(n) for n in (0, 1, 2, 3, 4, 5, 8, 9)] == \
        [1, 1, 2, 4, 4, 8, 8, 16]


def test_bucket_growth_matches_exact_shapes(model):
    """A context growing across page AND bucket edges must produce the
    same tokens as the legacy exact-width path, with O(log) compiles."""
    cfg, params = model
    nb = 8
    n_steps = 22                       # pages 1..6 -> buckets 1,2,4,8

    # legacy: exact-width block tables, synchronous token pull
    pool = _mk_pool(cfg, nb)
    hist_ref = [7]
    for ctx in range(n_steps):
        bt = jnp.asarray([list(range(ctx // BS + 1))], jnp.int32)
        nxt, _, pool = paged_decode_step(
            params, pool, bt, jnp.asarray([ctx], jnp.int32),
            jnp.asarray([hist_ref[-1]], jnp.int32), cfg=cfg)
        hist_ref.append(int(nxt[0]))

    # runner: bucketed persistent device state, deferred sync
    pool = _mk_pool(cfg, nb)
    runner = DecodeRunner({"cfg": cfg, "params": params},
                          block_size=BS, trash_block=nb - 1)
    c0 = DecodeRunner.jit_cache_size()
    hist = [7]
    for ctx in range(n_steps):
        blocks = list(range(ctx // BS + 1))
        pool = runner.decode([DecodeRequestView(0, blocks, hist)], pool)
    runner.flush()
    assert hist == hist_ref
    max_pages = (n_steps - 1) // BS + 1
    bound = math.ceil(math.log2(max_pages)) + 1
    compiles = DecodeRunner.jit_cache_size() - c0
    assert compiles <= bound, (compiles, bound)
    assert runner.stats.rebuilds == compiles
    # steady state: only the rows whose block lists changed were uploaded
    assert runner.stats.rows_updated < n_steps


def test_multi_request_join_leave_matches_legacy(model):
    """Requests joining, leaving (preemption) and rejoining through the
    incremental row machinery must match the rebuild-everything path."""
    cfg, params = model
    nb = 16

    def blocks_of(base, ctx):
        return [base + i for i in range(ctx // BS + 1)]

    # schedule: rid -> (join_step, leave_step, rejoin_step)
    plan = {0: (0, None, None), 1: (0, 6, 10), 2: (3, None, None)}
    base = {0: 0, 1: 5, 2: 10}
    n_steps = 14

    def active_at(step):
        out = []
        for rid, (j, l, rj) in sorted(plan.items()):
            on = step >= j and (l is None or step < l or
                                (rj is not None and step >= rj))
            if on:
                out.append(rid)
        return out

    def run_legacy():
        pool = _mk_pool(cfg, nb)
        hist = {r: [11 + r] for r in plan}
        ctx = {r: 0 for r in plan}
        for step in range(n_steps):
            rids = active_at(step)
            np_pages = max(ctx[r] // BS + 1 for r in rids)
            B = len(plan)
            bt = np.full((B, np_pages), nb - 1, np.int32)
            cl = np.zeros((B,), np.int32)
            tk = np.zeros((B,), np.int32)
            for i, r in enumerate(rids):
                ids = blocks_of(base[r], ctx[r])
                bt[i, :len(ids)] = ids
                cl[i] = ctx[r]
                tk[i] = hist[r][-1]
            nxt, _, pool = paged_decode_step(
                params, pool, jnp.asarray(bt), jnp.asarray(cl),
                jnp.asarray(tk), cfg=cfg)
            nxt = np.asarray(nxt)
            for i, r in enumerate(rids):
                hist[r].append(int(nxt[i]))
                ctx[r] += 1
        return hist

    def run_runner():
        pool = _mk_pool(cfg, nb)
        runner = DecodeRunner({"cfg": cfg, "params": params},
                              block_size=BS, trash_block=nb - 1)
        hist = {r: [11 + r] for r in plan}
        ctx = {r: 0 for r in plan}
        for step in range(n_steps):
            rids = active_at(step)
            views = [DecodeRequestView(r, blocks_of(base[r], ctx[r]),
                                       hist[r]) for r in rids]
            pool = runner.decode(views, pool)
            for r in rids:
                ctx[r] += 1
        runner.flush()
        return hist

    legacy, ours = run_legacy(), run_runner()
    for r in plan:
        assert ours[r] == legacy[r], f"rid {r} tokens diverged"


def test_swap_round_trip_bit_exact_and_kernel_parity(model):
    """Donation safety: after a swap-out/swap-in round trip the pool is
    bit-identical, and the multi-page-tile kernel still matches the
    pure-jnp reference on the round-tripped pool."""
    cfg, params = model
    spec = PoolSpec(n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads,
                    head_dim=cfg.resolved_head_dim, block_size=BS,
                    num_gpu_blocks=10, num_cpu_blocks=10)
    pools = PagedPools(spec)
    key = jax.random.PRNGKey(5)
    pools.gpu = jax.random.normal(key, pools.gpu.shape).astype(jnp.bfloat16)
    snap = np.asarray(pools.gpu, np.float32)

    used = [1, 3, 4, 6]
    pools.copy_out(used, [0, 1, 2, 3])
    pools.gpu = jnp.zeros_like(pools.gpu)
    pools.copy_in([0, 1, 2, 3], used)
    got = np.asarray(pools.gpu, np.float32)
    np.testing.assert_array_equal(got[:, :, used], snap[:, :, used])

    # kernel vs reference on the round-tripped pool, ppcb > 1, ragged tile
    kp, vp = pools.gpu[0, 0], pools.gpu[0, 1]
    q = jax.random.normal(key, (2, cfg.n_heads, cfg.resolved_head_dim),
                          jnp.bfloat16)
    bt = jnp.asarray([[1, 3, 4], [6, 4, 1]], jnp.int32)
    ctx = jnp.asarray([3 * BS, 2 * BS - 1], jnp.int32)
    scale = cfg.resolved_head_dim ** -0.5
    out = paged_attention(q, kp, vp, bt, ctx, scale,
                          pages_per_compute_block=2)
    ref = paged_attention_ref(q, jnp.stack([kp, vp]), bt, ctx, scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_decode_after_swap_round_trip_matches_no_swap(model):
    """Pool donation + the swap channel: swapping a request's KV out and
    back mid-generation must not change any subsequent token."""
    cfg, params = model
    spec = PoolSpec(n_layers=cfg.n_layers, n_kv_heads=cfg.n_kv_heads,
                    head_dim=cfg.resolved_head_dim, block_size=BS,
                    num_gpu_blocks=12, num_cpu_blocks=12)
    n_steps, swap_at = 10, 5

    def run(with_swap):
        pools = PagedPools(spec)
        runner = DecodeRunner({"cfg": cfg, "params": params},
                              block_size=BS, trash_block=11)
        hist = [3]
        for ctx in range(n_steps):
            if with_swap and ctx == swap_at:
                runner.flush()
                used = list(range(ctx // BS + 1))
                pools.copy_out(used, used)
                pools.gpu = jnp.zeros_like(pools.gpu)
                pools.copy_in(used, used)
            blocks = list(range(ctx // BS + 1))
            pools.gpu = runner.decode(
                [DecodeRequestView(0, blocks, hist)], pools.gpu)
        runner.flush()
        return hist

    assert run(with_swap=True) == run(with_swap=False)


def test_turn_boundary_context_jump_same_bucket(model):
    """A request whose context jumps OUTSIDE the decode loop (turn-end →
    sleep → re-admission prefill extends the history) while its rid never
    leaves the decode batch must be re-registered: the new page count
    stays inside the old bucket, so no rebuild masks a stale device
    ctx/token (regression: review finding on _update_rows)."""
    cfg, params = model
    nb = 8
    key = jax.random.PRNGKey(1)

    def prefill_write(pool, hist):
        # engine-style re-prefill: KV for all but the last history token
        from repro.models.paged import prefill_kv
        _, k, v = prefill_kv(params, jnp.asarray([hist[:-1]], jnp.int32),
                             cfg=cfg)
        k, v = np.asarray(k), np.asarray(v)
        T = k.shape[1]
        for t0 in range(0, T, BS):
            t1 = min(t0 + BS, T)
            blk = t0 // BS
            pool = pool.at[:, 0, blk, :t1 - t0].set(
                jnp.asarray(k[:, t0:t1], jnp.bfloat16))
            pool = pool.at[:, 1, blk, :t1 - t0].set(
                jnp.asarray(v[:, t0:t1], jnp.bfloat16))
        return pool

    turn2_prompt = [101, 202]
    n1, n2 = 10, 4            # turn 1 reaches pages 3 (bucket 4); turn 2
                              # starts at pages 4 — same bucket, no rebuild

    def run_legacy():
        pool = _mk_pool(cfg, nb)
        hist = [5]
        ctx = 0
        for _ in range(n1):
            bt = jnp.asarray([list(range(ctx // BS + 1))], jnp.int32)
            nxt, _, pool = paged_decode_step(
                params, pool, bt, jnp.asarray([ctx], jnp.int32),
                jnp.asarray([hist[-1]], jnp.int32), cfg=cfg)
            hist.append(int(nxt[0]))
            ctx += 1
        hist.extend(turn2_prompt)
        pool = prefill_write(pool, hist)
        ctx = len(hist) - 1
        for _ in range(n2):
            bt = jnp.asarray([list(range(ctx // BS + 1))], jnp.int32)
            nxt, _, pool = paged_decode_step(
                params, pool, bt, jnp.asarray([ctx], jnp.int32),
                jnp.asarray([hist[-1]], jnp.int32), cfg=cfg)
            hist.append(int(nxt[0]))
            ctx += 1
        return hist

    def run_runner():
        pool = _mk_pool(cfg, nb)
        runner = DecodeRunner({"cfg": cfg, "params": params},
                              block_size=BS, trash_block=nb - 1)
        hist = [5]
        ctx = 0
        for _ in range(n1):
            pool = runner.decode(
                [DecodeRequestView(0, list(range(ctx // BS + 1)), hist)],
                pool)
            ctx += 1
        runner.flush()            # engine flushes before reading history
        hist.extend(turn2_prompt)
        pool = prefill_write(pool, hist)
        ctx = len(hist) - 1
        for _ in range(n2):
            pool = runner.decode(
                [DecodeRequestView(0, list(range(ctx // BS + 1)), hist)],
                pool)
            ctx += 1
        runner.flush()
        assert runner.stats.rebuilds == 3      # buckets 1, 2, 4 — no 4th
        return hist

    assert run_runner() == run_legacy()


def test_runner_prefill_matches_host_write_path(model):
    """Runner-managed prefill insertion (jitted bucketed scatter +
    direct row registration) vs the legacy host path
    (``PagedPools.write_tokens``-style per-block writes + exact-shape
    decode): bit-identical token streams across a prefill, a decode
    stretch, a turn-boundary re-prefill and another decode stretch."""
    cfg, params = model
    nb = 16
    prompt = [int(x) for x in
              np.random.RandomState(3).randint(1, cfg.vocab_size, 9)]
    turn2 = [101, 202, 303]
    n1, n2 = 6, 4

    def legacy():
        from repro.models.paged import prefill_kv
        pool = _mk_pool(cfg, nb)
        hist = list(prompt)

        def host_prefill(pool, toks):
            logits, k, v = prefill_kv(params,
                                      jnp.asarray([toks], jnp.int32), cfg=cfg)
            k, v = np.asarray(k), np.asarray(v)
            for t0 in range(0, k.shape[1], BS):
                t1 = min(t0 + BS, k.shape[1])
                blk = t0 // BS
                pool = pool.at[:, 0, blk, :t1 - t0].set(
                    jnp.asarray(k[:, t0:t1], jnp.bfloat16))
                pool = pool.at[:, 1, blk, :t1 - t0].set(
                    jnp.asarray(v[:, t0:t1], jnp.bfloat16))
            return pool, logits

        def decode(pool, hist, steps):
            for _ in range(steps):
                ctx = len(hist) - 1
                bt = jnp.asarray([list(range(ctx // BS + 1))], jnp.int32)
                nxt, _, pool = paged_decode_step(
                    params, pool, bt, jnp.asarray([ctx], jnp.int32),
                    jnp.asarray([hist[-1]], jnp.int32), cfg=cfg)
                hist.append(int(nxt[0]))
            return pool

        pool, logits = host_prefill(pool, hist)
        hist.append(int(np.argmax(np.asarray(logits))))
        pool = decode(pool, hist, n1)
        hist.extend(turn2)
        pool, logits = host_prefill(pool, hist)
        hist.append(int(np.argmax(np.asarray(logits))))
        decode(pool, hist, n2)
        return hist

    def runner_path():
        from repro.kernels.ops import insert_prefill_cache_size
        pool = _mk_pool(cfg, nb)
        runner = DecodeRunner({"cfg": cfg, "params": params},
                              block_size=BS, trash_block=nb - 1)
        c0 = insert_prefill_cache_size()
        hist = list(prompt)

        def blocks(ctx):
            return list(range(ctx // BS + 1))

        pool = runner.prefill(
            DecodeRequestView(0, blocks(len(hist) - 1), hist), pool,
            emit_first=True)
        for _ in range(n1):
            ctx = len(hist) - 1       # flush() inside decode keeps this
            pool = runner.decode(     # current: single-request lockstep
                [DecodeRequestView(0, blocks(ctx), hist)], pool)
            runner.flush()
        hist.extend(turn2)
        pool = runner.prefill(
            DecodeRequestView(0, blocks(len(hist) - 1), hist), pool,
            emit_first=True)
        for _ in range(n2):
            ctx = len(hist) - 1
            pool = runner.decode(
                [DecodeRequestView(0, blocks(ctx), hist)], pool)
            runner.flush()
        assert runner.stats.prefills == 2
        # shape-bucketed insert: one compiled variant per pow2 page bucket
        assert insert_prefill_cache_size() - c0 <= \
            math.ceil(math.log2(nb)) + 1
        return hist

    assert runner_path() == legacy()


def test_flush_is_idempotent_and_deferred(model):
    cfg, params = model
    pool = _mk_pool(cfg, 4)
    runner = DecodeRunner({"cfg": cfg, "params": params},
                          block_size=BS, trash_block=3)
    hist = [9]
    pool = runner.decode([DecodeRequestView(0, [0], hist)], pool)
    assert len(hist) == 1          # token still on device
    runner.flush()
    assert len(hist) == 2          # materialized exactly once
    runner.flush()
    assert len(hist) == 2
    assert runner.stats.host_syncs == 1
