"""Staged swap data plane (ISSUE 3): run-coalesced gather/scatter KV
integrity, donation/rebind safety, chunked dispatch semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache.paged import PagedPools, PoolSpec
from repro.kernels import ops
from repro.kernels.block_copy import runs_to_indices, split_runs, trim_runs

BS = 8


def _pools(nb=16, ncpu=24, layers=2, heads=2, dim=8):
    spec = PoolSpec(n_layers=layers, n_kv_heads=heads, head_dim=dim,
                    block_size=BS, num_gpu_blocks=nb, num_cpu_blocks=ncpu)
    pools = PagedPools(spec)
    pools.gpu = jax.random.normal(
        jax.random.PRNGKey(7), pools.gpu.shape).astype(jnp.bfloat16)
    return pools


def test_cpu_pool_stores_bf16_bit_pattern():
    """uint16 host pool: half the float32 footprint, bit-exact round trip."""
    pools = _pools()
    assert pools.cpu.dtype == np.uint16
    assert pools.cpu.nbytes * 2 == pools.cpu.astype(np.float32).nbytes
    assert pools.cpu_bf16().dtype == jnp.bfloat16


def test_staged_round_trip_bit_exact_scattered_runs():
    pools = _pools()
    snap = np.asarray(pools.gpu)
    runs = [(1, 3), (6, 2), (11, 1)]
    blocks = runs_to_indices(runs)
    cpu_ids = [5, 0, 9, 2, 17, 21]                  # scattered on purpose
    pools.copy_out_staged(runs, cpu_ids)
    before = pools.gpu
    pools.gpu = jnp.zeros_like(pools.gpu)
    pools.copy_in_staged(cpu_ids, runs)
    got = np.asarray(pools.gpu)
    np.testing.assert_array_equal(got[:, :, blocks], snap[:, :, blocks])
    # donation safety: the rebind installed a NEW owner-of-record array
    assert pools.gpu is not before
    # untouched blocks of the donated pool must be preserved (zeros here)
    other = [b for b in range(16) if b not in blocks]
    assert not np.any(got[:, :, other]), "scatter leaked into other blocks"


def test_staged_matches_host_baseline_bitwise():
    """Same blocks through the staged path and the legacy host-mediated
    path must produce identical uint16 CPU pools and GPU pools."""
    p1, p2 = _pools(), _pools()
    runs = [(0, 2), (5, 4)]
    blocks = runs_to_indices(runs)
    cpu_ids = list(range(len(blocks)))
    p1.copy_out_staged(runs, cpu_ids)
    p2.copy_out(blocks, cpu_ids)
    np.testing.assert_array_equal(p1.cpu, p2.cpu)
    p1.gpu = jnp.zeros_like(p1.gpu)
    p2.gpu = jnp.zeros_like(p2.gpu)
    p1.copy_in_staged(cpu_ids, runs)
    p2.copy_in(cpu_ids, blocks)
    np.testing.assert_array_equal(np.asarray(p1.gpu), np.asarray(p2.gpu))


def test_staged_round_trip_partial_last_block():
    """A context ending mid-block: the whole last block round-trips (the
    tail beyond the context is masked by attention, but the engine's
    read_tokens view of the valid prefix must be bit-exact)."""
    pools = _pools()
    n_tokens = 2 * BS + 3                           # partial third block
    L, H, D = 2, 2, 8
    rng = np.random.RandomState(0)
    k = rng.randn(L, n_tokens, H, D).astype(np.float32)
    v = rng.randn(L, n_tokens, H, D).astype(np.float32)
    block_ids = [4, 9, 2]
    pools.write_tokens(block_ids, 0, k, v)
    k0, v0 = pools.read_tokens(block_ids, n_tokens)
    runs = [(4, 1), (9, 1), (2, 1)]
    cpu_ids = [0, 1, 2]
    pools.copy_out_staged(runs, cpu_ids)
    pools.gpu = jnp.zeros_like(pools.gpu)
    pools.copy_in_staged(cpu_ids, runs)
    k1, v1 = pools.read_tokens(block_ids, n_tokens)
    np.testing.assert_array_equal(k0, k1)
    np.testing.assert_array_equal(v0, v1)


def test_multi_turn_reuse_increments_round_trip():
    """The reuse mechanism swaps out INCREMENTS across turns (only tokens
    beyond the valid CPU prefix); after several increments a full staged
    swap-in must restore every block bit-exactly."""
    pools = _pools(nb=12, ncpu=12)
    snap = np.asarray(pools.gpu)
    gpu_ids = [3, 4, 5, 8, 9, 10]                   # two gpu runs
    cpu_ids = [0, 1, 2, 3, 4, 5]
    # turn 1: blocks 0..2 of the request; turn 2: blocks 3..4; turn 3: 5
    for lo, hi in ((0, 3), (3, 5), (5, 6)):
        runs = [(s, 1) for s in gpu_ids[lo:hi]]
        pools.copy_out_staged(runs, cpu_ids[lo:hi])
    pools.gpu = jnp.zeros_like(pools.gpu)
    pools.copy_in_staged(cpu_ids, [(3, 3), (8, 3)])
    got = np.asarray(pools.gpu)
    np.testing.assert_array_equal(got[:, :, gpu_ids], snap[:, :, gpu_ids])


def test_gather_scatter_bucketing_bounds_jit_cache():
    """Pow2 bucketing: a single-run swap growing from 1 to 20 blocks
    compiles O(log2) variants (not one per size), and repeating any shape
    compiles nothing new."""
    pools = _pools(nb=40, ncpu=64)
    g0, s0 = ops.swap_gather_cache_size(), ops.swap_scatter_cache_size()

    def sweep():
        for n in range(1, 21):
            pools.copy_out_staged([(0, n)], list(range(n)))
            pools.copy_in_staged(list(range(n)), [(0, n)])
    sweep()
    grown_g = ops.swap_gather_cache_size() - g0
    grown_s = ops.swap_scatter_cache_size() - s0
    assert grown_g <= 6, grown_g              # ceil(log2(20)) + 1
    assert grown_s <= 6, grown_s
    sweep()                                   # warm: zero new variants
    assert ops.swap_gather_cache_size() - g0 == grown_g
    assert ops.swap_scatter_cache_size() - s0 == grown_s


def test_copy_in_double_buffered_bit_exact_multi_stage():
    """Double-buffered swap-in (bounded sub-slabs): splitting a staged
    copy-in mid-run must land every block bit-exactly, leak into no
    others, and keep the per-stage transfer accounting
    (``h2d_transfers == n_shards * staged_in_calls``)."""
    pools = _pools()
    snap = np.asarray(pools.gpu)
    runs = [(1, 3), (6, 2), (11, 2)]                # 7 blocks, 3 runs
    blocks = runs_to_indices(runs)
    cpu_ids = [5, 0, 9, 2, 17, 21, 3]
    pools.copy_out_staged(runs, cpu_ids)
    pools.gpu = jnp.zeros_like(pools.gpu)
    in0, h0 = pools.staged_in_calls, pools.h2d_transfers
    pools.copy_in_staged(cpu_ids, runs, stage_blocks=3)
    n_stages = len(split_runs(runs, 3))             # 3 — splits (1,3) off
    assert n_stages == 3
    assert pools.staged_in_calls - in0 == n_stages
    assert pools.h2d_transfers - h0 == pools.n_shards * n_stages
    got = np.asarray(pools.gpu)
    np.testing.assert_array_equal(got[:, :, blocks], snap[:, :, blocks])
    other = [b for b in range(16) if b not in blocks]
    assert not np.any(got[:, :, other]), "stage scatter leaked"


def test_copy_in_stage_split_matches_monolithic_slab():
    """stage_blocks=0 (one monolithic slab) and a multi-stage split of
    the SAME swap-in produce bit-identical pools."""
    p1, p2 = _pools(), _pools()
    runs = [(0, 4), (8, 4)]
    cpu_ids = list(range(8))
    for p in (p1, p2):
        p.copy_out_staged(runs, cpu_ids)
        p.gpu = jnp.zeros_like(p.gpu)
    in1, in2 = p1.staged_in_calls, p2.staged_in_calls
    p1.copy_in_staged(cpu_ids, runs, stage_blocks=0)
    p2.copy_in_staged(cpu_ids, runs, stage_blocks=3)
    assert p1.staged_in_calls - in1 == 1            # single shot
    assert p2.staged_in_calls - in2 == len(split_runs(runs, 3))
    np.testing.assert_array_equal(np.asarray(p1.gpu), np.asarray(p2.gpu))


def test_split_and_trim_runs():
    runs = [(0, 5), (10, 2), (20, 1)]
    assert split_runs(runs, 0) == [runs]
    assert split_runs([], 4) == []
    chunks = split_runs(runs, 3)
    assert chunks == [[(0, 3)], [(3, 2), (10, 1)], [(11, 1), (20, 1)]]
    assert runs_to_indices([r for c in chunks for r in c]) \
        == runs_to_indices(runs)
    assert trim_runs(runs, 6) == [(0, 5), (10, 1)]
    assert trim_runs(runs, 0) == []
    assert trim_runs(runs, 99) == runs


# ---------------------------------------------------------------------------
# engine-level: chunked dispatch, donation safety, batch-bucket admission
# ---------------------------------------------------------------------------


def _sim_engine(**kw):
    from repro.core import EngineConfig, FastSwitchEngine
    from repro.data.priority import PriorityTrace
    from repro.data.sharegpt import Conversation, Turn
    convs = [Conversation(conv_id=0, arrival_s=0.0, turns=[Turn(8, 20)],
                          think_time_s=0.1)]
    defaults = dict(mode="sim", num_gpu_blocks=64, num_cpu_blocks=256,
                    block_size=16)
    defaults.update(kw)
    cfg = EngineConfig(**defaults).with_policy("fastswitch")
    return FastSwitchEngine(cfg, convs,
                            trace=PriorityTrace("random", 1e-9, seed=0))


def test_chunked_swap_in_promotes_only_when_all_chunks_done():
    """A swap split into chunk tasks: the request must stay SWAPPING_IN
    until its LAST chunk completes (the old per-task promotion would have
    promoted on the first)."""
    from repro.core.scheduler import ReqState
    eng = _sim_engine(swap_chunk_blocks=1, num_gpu_blocks=512)
    eng.swap.adaptive = False                 # force async swaps
    eng.step()
    req = eng.sched.requests[0]
    # grow the context so the swap spans several 1-block chunks
    grow = 4 * 16 - req.context_tokens
    eng.gpu_mgr.allocate_tokens(0, grow)
    eng.gpu_mgr.note_tokens(0, grow)
    req.context_tokens += grow
    eng._preempt(0)
    assert eng._swap_in(0) is False
    tasks = [t for t in eng.swap.ongoing_swap_in if t.req_id == 0]
    assert len(tasks) >= 3, "swap was not split into chunk tasks"
    # advance to just past the FIRST chunk: must not be promoted yet
    eng.clock.advance_to(min(t.done_at for t in tasks) + 1.0)
    eng.swap.poll_completed(eng.clock)
    ongoing = {t.req_id for t in eng.swap.ongoing_swap_in}
    assert 0 in ongoing
    eng.step()
    assert req.state == ReqState.SWAPPING_IN, \
        "request promoted before all chunk tasks completed"
    eng.clock.advance_to(max(t.done_at for t in tasks) + 1.0)
    eng.step()
    # promoted once every chunk retired (the inflated context makes the
    # turn finish in the same iteration, so DONE also proves promotion)
    assert req.state in (ReqState.RUNNING, ReqState.DONE)


def test_conflict_sync_waits_only_overlapping_chunk():
    """Fine-grained chunk conflicts: resolving a conflict on one chunk's
    blocks must retire only that chunk, not the whole swap."""
    eng = _sim_engine(swap_chunk_blocks=1, num_gpu_blocks=512)
    eng.swap.adaptive = False
    eng.step()
    req = eng.sched.requests[0]
    grow = 4 * 16 - req.context_tokens
    eng.gpu_mgr.allocate_tokens(0, grow)
    eng.gpu_mgr.note_tokens(0, grow)
    req.context_tokens += grow
    eng._preempt(0)
    eng._swap_in(0)
    tasks = [t for t in eng.swap.ongoing_swap_in if t.req_id == 0]
    assert len(tasks) >= 3
    first = tasks[0]
    eng.swap.resolve_conflicts(eng.clock, list(first.gpu_blocks))
    remaining = [t for t in eng.swap.ongoing_swap_in if t.req_id == 0]
    assert first not in remaining
    assert len(remaining) == len(tasks) - 1, \
        "conflict sync retired more than the overlapping chunk"


def test_swap_in_dispatches_token_ordered_runs_on_fragmented_alloc():
    """A fragmented pool can satisfy a swap-in with groups whose physical
    starts DESCEND (block table [8..12, 0..2]).  The data plane pairs GPU
    runs positionally with the token-ordered CPU block list, so the runs
    must follow TOKEN order — ``request_runs``' physically-sorted spans
    would restore every block into the wrong block-table slot."""
    from repro.core.block_group import BlockGroup, _ReqState
    from repro.core.scheduler import ReqState
    eng = _sim_engine(num_gpu_blocks=64)
    eng.swap.adaptive = False
    eng.step()                              # admit rid 0
    req = eng.sched.requests[0]
    # hand-craft a descending-start allocation: tokens 0..79 -> blocks
    # 8..12, tokens 80..127 -> blocks 0..2
    eng.gpu_mgr.release_request(0)
    eng.gpu_mgr.requests[0] = _ReqState(groups=[
        BlockGroup(start=8, length=5, owner=0, used=5),
        BlockGroup(start=0, length=3, owner=0, used=3)])
    eng.gpu_mgr._token_counts[0] = 8 * 16
    assert eng.gpu_mgr.request_runs(0) == [(0, 3), (8, 5)]   # sorted (wrong)
    eng.gpu_mgr.allocate_tokens = lambda rid, n: []          # keep crafted
    eng.gpu_mgr.note_tokens = lambda rid, n: None            # state as-is
    req.context_tokens = 8 * 16
    eng.sched.move(0, ReqState.SWAPPED)
    captured = []
    orig = eng.swap.dispatch
    eng.swap.dispatch = lambda clock, rid, d, runs, *a, **k: \
        captured.append(list(runs)) or orig(clock, rid, d, runs, *a, **k)
    eng._swap_in(0)
    flat = [r for runs in captured for r in runs]
    assert flat == [(8, 5), (0, 3)], \
        f"swap-in runs not in token order: {flat}"


def test_admission_target_sim_mode_is_max_running():
    eng = _sim_engine(max_running=16)
    assert eng._admission_target() == 16


def test_desired_running_trims_bucket_spill():
    """Scheduler-side batch-bucket economics: a one-request spill past the
    compiled bucket is trimmed (admissions only), a half-bucket spill is
    kept, and running requests are never trimmed."""
    from repro.core.scheduler import PriorityScheduler, Request, ReqState
    from repro.data.sharegpt import Conversation, Turn

    class _Trace:
        def priority(self, rid):
            return -rid           # rid 0 = highest priority

    sched = PriorityScheduler(_Trace(), max_running=48)
    for i in range(5):
        req = Request(conv=Conversation(conv_id=i, arrival_s=0.0,
                                        turns=[Turn(8, 8)],
                                        think_time_s=0.1))
        req.begin_turn(0.0)
        sched.add_request(req)
    budget = 10_000
    # no bucket hint: all 5 chosen
    assert len(sched.desired_running(budget, 16)) == 5
    # bucket 4: spill of 1 (< half of the next bucket's rows) -> trimmed
    assert len(sched.desired_running(budget, 16, batch_bucket=4)) == 4
    # bucket 2: 5 = boundary 4 + spill 1 < 2 -> trimmed to 4
    assert len(sched.desired_running(budget, 16, batch_bucket=2)) == 4
    # a running request at the tail is never trimmed: the trim skips it
    # and removes the lowest-priority non-running entry instead
    sched.move(4, ReqState.RUNNING)
    chosen = sched.desired_running(budget, 16, batch_bucket=4)
    assert len(chosen) == 4 and 4 in chosen and 3 not in chosen


def test_swap_in_copy_ordered_behind_queued_swap_out_data():
    """A swap-in reads CPU blocks that a still-queued swap-out of the
    same request writes; worker execution is not FIFO, so the in-copy
    must await the out-task's data future (``copy_deps``) — without it,
    a slow out-copy lets the in-copy restore stale zeros."""
    import time as _time
    from repro.core.swap_manager import MultithreadingSwapManager, SimClock
    from repro.io.cost_model import TPU_V5E_HOST

    def run(with_deps):
        pools = _pools(nb=8, ncpu=8)
        snap = np.asarray(pools.gpu)
        mgr = MultithreadingSwapManager(TPU_V5E_HOST, pools)
        clock = SimClock()
        runs_out, cpu_ids = [(2, 2)], [0, 1]
        runs_in = [(5, 2)]                     # swap-in relocates the blocks
        # model the race window: the out-worker is descheduled between
        # picking up the task and acquiring the pool lock
        orig_run = mgr._run_copy_guarded

        def delayed_run(task, deps):
            _time.sleep(0.2)
            return orig_run(task, deps)
        mgr._run_copy_guarded = delayed_run
        out = mgr.dispatch(clock, 1, "out", runs_out, 1024,
                           runs_to_indices(runs_out), asynchronous=True,
                           copy_fn=lambda: pools.copy_out_staged(runs_out,
                                                                 cpu_ids),
                           cpu_blocks=cpu_ids)
        mgr._run_copy_guarded = orig_run
        deps = mgr.data_deps(cpu_ids)
        assert deps == [out.future]
        # overlap-keyed: disjoint CPU blocks have no dependency, and a
        # cross-request write to the SAME blocks (contamination handing a
        # victim's CPU blocks to a new owner) does
        assert mgr.data_deps([7]) == []
        assert mgr.data_deps([cpu_ids[0]]) == [out.future]
        mgr.dispatch(clock, 1, "in", runs_in, 1024, runs_to_indices(runs_in),
                     asynchronous=True,
                     copy_fn=lambda: pools.copy_in_staged(cpu_ids, runs_in),
                     copy_deps=deps if with_deps else (),
                     cpu_blocks=cpu_ids)
        mgr.shutdown()                         # join both workers
        got = np.asarray(pools.gpu)
        return np.array_equal(got[:, :, [5, 6]], snap[:, :, [2, 3]])

    assert not run(with_deps=False), \
        "race did not reproduce — the scenario no longer tests ordering"
    assert run(with_deps=True), \
        "swap-in copy ran before the queued swap-out wrote CPU"


# ---------------------------------------------------------------------------
# real mode: chunked staged swaps preserve tokens under storm preemption
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_model():
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("qwen2-1.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return {"cfg": cfg, "params": params}


def test_real_chunked_storm_matches_unchunked(tiny_model):
    """swap_chunk_blocks=1 forces every storm swap through multi-chunk
    dispatch (chunk-granular conflict syncs, per-chunk pool-lock holds);
    the generated token streams must be identical to the unchunked run —
    and the engine must hold no stale pool reference across rebinds."""
    from repro.core import EngineConfig, FastSwitchEngine
    from repro.data.priority import PriorityTrace
    from repro.data.sharegpt import Conversation, Turn

    def run(chunk):
        convs = [Conversation(conv_id=i, arrival_s=0.0,
                              turns=[Turn(16, 20)], think_time_s=0.2)
                 for i in range(3)]
        cfg = EngineConfig(mode="real", num_gpu_blocks=8, num_cpu_blocks=512,
                           max_running=4, max_batch=4,
                           swap_chunk_blocks=chunk).with_policy("fastswitch")
        eng = FastSwitchEngine(
            cfg, convs, trace=PriorityTrace("random", 0.5, seed=11),
            model_bundle=tiny_model)
        eng.run(max_iterations=20_000)
        assert eng.done()
        return eng

    e1 = run(chunk=0)                      # unchunked
    e2 = run(chunk=1)                      # every block its own chunk task
    assert e2.metrics.preemptions > 0
    assert e1._token_hist_by_conv == e2._token_hist_by_conv
