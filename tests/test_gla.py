"""Chunked gated linear attention vs the sequential oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.gla import gla_chunked, gla_decode_step, gla_scan_ref


def _inputs(B, H, T, N, P, key, scalar_decay=False):
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, H, T, N)) * 0.5
    k = jax.random.normal(ks[1], (B, H, T, N)) * 0.5
    v = jax.random.normal(ks[2], (B, H, T, P)) * 0.5
    shape = (B, H, T, 1) if scalar_decay else (B, H, T, N)
    logw = -jnp.exp(jax.random.normal(ks[3], shape) * 0.5 - 1.0)
    return q, k, v, logw


@pytest.mark.parametrize("mode,scalar", [("mamba", True), ("mamba", False),
                                         ("rwkv", False)])
@pytest.mark.parametrize("T,chunk", [(64, 16), (128, 32), (96, 32), (32, 32)])
def test_chunked_matches_scan(mode, scalar, T, chunk):
    B, H, N, P = 2, 3, 16, 24
    q, k, v, logw = _inputs(B, H, T, N, P, jax.random.PRNGKey(0),
                            scalar_decay=scalar)
    u = 0.3 * jnp.ones((H, N)) if mode == "rwkv" else None
    lw = jnp.broadcast_to(logw, (B, H, T, N))
    ref, S_ref = gla_scan_ref(q, k, v, lw, u=u, mode=mode)
    out, S = gla_chunked(q, k, v, lw, u=u, mode=mode, chunk=chunk,
                         scalar_decay=scalar)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("mode", ["mamba", "rwkv"])
def test_decode_continues_state(mode):
    """Chunked pass over T tokens == chunked over T-4 + 4 decode steps."""
    B, H, T, N, P = 1, 2, 32, 8, 8
    q, k, v, logw = _inputs(B, H, T, N, P, jax.random.PRNGKey(1))
    u = 0.5 * jnp.ones((H, N)) if mode == "rwkv" else None
    full, S_full = gla_scan_ref(q, k, v, logw, u=u, mode=mode)
    part, S = gla_scan_ref(q[:, :, :T - 4], k[:, :, :T - 4],
                           v[:, :, :T - 4], logw[:, :, :T - 4],
                           u=u, mode=mode)
    outs = []
    for t in range(T - 4, T):
        y, S = gla_decode_step(q[:, :, t], k[:, :, t], v[:, :, t],
                               logw[:, :, t], S, u=u, mode=mode)
        outs.append(y)
    tail = jnp.stack(outs, axis=2)
    np.testing.assert_allclose(np.asarray(tail),
                               np.asarray(full[:, :, T - 4:]),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_full),
                               atol=1e-4, rtol=1e-4)


def test_initial_state_carries():
    B, H, T, N, P = 1, 1, 32, 8, 8
    q, k, v, logw = _inputs(B, H, T, N, P, jax.random.PRNGKey(2))
    full, _ = gla_chunked(q, k, v, logw, mode="mamba", chunk=16)
    h1, S1 = gla_chunked(q[:, :, :16], k[:, :, :16], v[:, :, :16],
                         logw[:, :, :16], mode="mamba", chunk=16)
    h2, _ = gla_chunked(q[:, :, 16:], k[:, :, 16:], v[:, :, 16:],
                        logw[:, :, 16:], mode="mamba", chunk=16,
                        initial_state=S1)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(full[:, :, 16:]),
                               atol=1e-4, rtol=1e-4)
