"""Hypothesis property tests for the Dynamic Block Group Manager."""
import random

import pytest

pytest.importorskip("hypothesis",
                    reason="dev-only dep; see requirements-dev.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.block_group import (DynamicBlockGroupManager,
                                    OutOfBlocksError)


def _apply_ops(mgr, ops):
    """ops: list of (req_id, n_tokens) alloc or (req_id, None) release."""
    live = set()
    for rid, n in ops:
        if n is None:
            if rid in live:
                mgr.release_request(rid)
                live.discard(rid)
        else:
            try:
                mgr.allocate_tokens(rid, n)
                mgr.note_tokens(rid, n)
                live.add(rid)
            except OutOfBlocksError:
                pass
        mgr.check_invariants()
    return live


@settings(max_examples=200, deadline=None)
@given(st.lists(
    st.tuples(st.integers(0, 7),
              st.one_of(st.none(), st.integers(1, 300))),
    min_size=1, max_size=60),
    st.integers(1, 64))
def test_no_overlap_no_leak(ops, group_blocks):
    mgr = DynamicBlockGroupManager(128, 16, initial_group_blocks=group_blocks)
    live = _apply_ops(mgr, ops)
    # full accounting: free + owned == capacity
    owned = sum(g.length for st_ in mgr.requests.values() for g in st_.groups)
    assert owned + mgr.free_blocks() == mgr.num_blocks
    # releasing everything returns the pool to one merged group
    for rid in list(live):
        mgr.release_request(rid)
    mgr.check_invariants()
    assert mgr.free_blocks() == mgr.num_blocks
    assert len(mgr.free) == 1, "free list must fully merge"


@settings(max_examples=100, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(1, 200)),
                min_size=1, max_size=40))
def test_capacity_covers_tokens(allocs):
    """A request's block capacity always covers its recorded tokens."""
    mgr = DynamicBlockGroupManager(256, 16, initial_group_blocks=60)
    for rid, n in allocs:
        try:
            mgr.allocate_tokens(rid, n)
            mgr.note_tokens(rid, n)
        except OutOfBlocksError:
            continue
        st_ = mgr.requests[rid]
        cap = st_.used_blocks() * mgr.block_size_tokens
        assert cap >= mgr.request_tokens(rid)
        mgr.check_invariants()


@settings(max_examples=100, deadline=None)
@given(st.integers(1, 64), st.integers(1, 500))
def test_block_table_is_consistent(group_blocks, tokens):
    mgr = DynamicBlockGroupManager(512, 16, initial_group_blocks=group_blocks)
    mgr.allocate_tokens(1, tokens)
    mgr.note_tokens(1, tokens)
    ids = mgr.request_block_ids(1)
    assert len(ids) == len(set(ids)), "block table must not repeat blocks"
    need = (tokens + 15) // 16
    assert len(ids) >= need
    runs = mgr.request_runs(1)
    assert sum(n for _, n in runs) == len(ids)
    # runs are maximal: no two adjacent
    for (s1, n1), (s2, n2) in zip(runs, runs[1:]):
        assert s1 + n1 < s2


def test_steal_from_active_group():
    mgr = DynamicBlockGroupManager(64, 16, initial_group_blocks=60)
    mgr.allocate_tokens(1, 16)          # gets a (shrunk) group, uses 1 block
    mgr.note_tokens(1, 16)
    free_before = mgr.free_blocks()
    tail = mgr.requests[1].active.free_tail
    assert tail > 0
    # demand more than the free pool: forces a steal from req 1's tail
    want = free_before + 2
    mgr.allocate_tokens(2, 16 * want)
    mgr.note_tokens(2, 16 * want)
    mgr.check_invariants()
    assert mgr.n_steals >= 1
    assert len(mgr.request_block_ids(2)) == want


def test_vllm_baseline_is_per_block():
    mgr = DynamicBlockGroupManager(64, 16, initial_group_blocks=1)
    mgr.allocate_tokens(1, 16 * 5)
    mgr.note_tokens(1, 16 * 5)
    st_ = mgr.requests[1]
    assert all(g.length == 1 for g in st_.groups)


def test_oom_raises():
    mgr = DynamicBlockGroupManager(4, 16, initial_group_blocks=1)
    mgr.allocate_tokens(1, 16 * 4)
    mgr.note_tokens(1, 64)
    with pytest.raises(OutOfBlocksError):
        mgr.allocate_tokens(2, 16)


def test_merge_restores_contiguity():
    mgr = DynamicBlockGroupManager(100, 16, initial_group_blocks=10)
    for rid in range(5):
        mgr.allocate_tokens(rid, 16 * 10)
        mgr.note_tokens(rid, 160)
    for rid in [1, 3]:
        mgr.release_request(rid)
    mgr.check_invariants()
    for rid in [0, 2, 4]:
        mgr.release_request(rid)
    assert len(mgr.free) == 1 and mgr.free_blocks() == 100
