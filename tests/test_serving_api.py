"""Open-world serving API (core/serving.py, ISSUE 5): lifecycle of
``add_request/step/abort/continue_session``, driver equivalence between
the trace-replay client and a hand-rolled online client, per-request
SLO attainment and the event stream."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EngineConfig, FastSwitchEngine, SamplingParams,
                        ServingEngine, SLOSpec)
from repro.data.priority import PriorityTrace
from repro.data.sharegpt import (Conversation, Turn, sample_conversations,
                                 synth_prompt_ids)


def _sim_engine(**kw):
    trace = kw.pop("trace", None) or PriorityTrace("random", 1e-9, seed=0)
    defaults = dict(mode="sim", num_gpu_blocks=128, num_cpu_blocks=512,
                    max_running=8)
    defaults.update(kw)
    return ServingEngine(EngineConfig(**defaults).with_policy("fastswitch"),
                         trace=trace)


def _drain(engine, max_iters=50_000):
    outs = []
    it = 0
    while engine.has_work() and it < max_iters:
        outs.extend(engine.step())
        it += 1
    assert not engine.has_work(), "engine did not drain"
    return outs


# ---------------------------------------------------------------------------
# basic lifecycle
# ---------------------------------------------------------------------------


def test_online_sim_lifecycle_and_output_contract():
    eng = _sim_engine()
    h1 = eng.add_request(10, SamplingParams(max_tokens=5))
    h2 = eng.add_request(8, SamplingParams(max_tokens=3))
    assert h1 != h2
    outs = _drain(eng)
    per = {h1: 0, h2: 0}
    for o in outs:
        per[o.handle] += o.new_tokens
    # per-request max_tokens honored exactly
    assert per == {h1: 5, h2: 3}
    # exactly one first-token marker per request, carrying its TTFT
    firsts = [o for o in outs if o.first_token]
    assert sorted(o.handle for o in firsts) == sorted([h1, h2])
    assert all(o.ttft_us is not None and o.ttft_us >= 0 for o in firsts)
    fins = [o for o in outs if o.finished]
    assert sorted(o.handle for o in fins) == sorted([h1, h2])
    assert all(o.finish_reason == "length" for o in fins)
    # event stream: arrive .. first_token .. finish, per handle, in order
    for h in (h1, h2):
        kinds = [e.kind for e in eng.events if e.handle == h]
        assert kinds[0] == "arrive" and kinds[-1] == "finish"
        assert kinds.index("first_token") < len(kinds) - 1
    eng.shutdown()


def test_add_request_validation():
    eng = _sim_engine()
    with pytest.raises(ValueError):
        eng.add_request(0)                      # empty prompt
    with pytest.raises(ValueError):
        eng.add_request(4, SamplingParams(max_tokens=0))
    h = eng.add_request(4)
    with pytest.raises(ValueError):
        eng.add_request(4, handle=h)            # handle collision
    # continue_session: live handle rejected, unknown handle rejected
    with pytest.raises(ValueError):
        eng.continue_session(h, 4)
    with pytest.raises(KeyError):
        eng.continue_session(12345, 4)
    assert eng.release_session(12345) is False
    eng.shutdown()


def test_retained_session_parks_and_releases():
    eng = _sim_engine()
    h = eng.add_request(6, SamplingParams(max_tokens=4), retain_kv=True)
    _drain(eng)
    assert h in eng.parked
    assert eng.reuse.valid_tokens(h) > 0        # CPU copy retained
    # follow-up turn reuses the prefix instead of re-prefilling it
    eng.continue_session(h, 5, SamplingParams(max_tokens=3))
    outs = _drain(eng)
    assert sum(o.new_tokens for o in outs if o.handle == h) == 3
    assert h not in eng.parked
    # second turn did NOT retain: copy released at finish
    assert eng.reuse.valid_tokens(h) == 0
    eng.shutdown()


def test_release_session_frees_cpu_copy():
    eng = _sim_engine()
    h = eng.add_request(6, SamplingParams(max_tokens=4), retain_kv=True)
    _drain(eng)
    free0 = eng.reuse.mgr.free_blocks()
    assert eng.release_session(h) is True
    assert h not in eng.parked
    assert eng.reuse.mgr.free_blocks() > free0
    assert eng.reuse.mgr.free_blocks() == eng.reuse.mgr.num_blocks
    eng.shutdown()


def test_real_mode_rejects_count_prompts_validates_sampling():
    pytest.importorskip("jax")
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    cfg_m = get_smoke_config("qwen2-1.5b")
    params = T.init_params(cfg_m, jax.random.PRNGKey(0))
    cfg = EngineConfig(mode="real", num_gpu_blocks=32, num_cpu_blocks=64,
                       max_running=2, max_batch=2).with_policy("fastswitch")
    eng = ServingEngine(cfg, trace=PriorityTrace("random", 1e-9, seed=0),
                        model_bundle={"cfg": cfg_m, "params": params})
    with pytest.raises(ValueError):
        eng.add_request(10)                     # counts are sim-only
    # out-of-range sampling params are rejected at add_request; IN-range
    # overrides are accepted (per-row (B, 3) sampling, ISSUE 8)
    with pytest.raises(ValueError):
        eng.add_request([1, 2, 3], SamplingParams(max_tokens=2,
                                                  temperature=-0.5))
    with pytest.raises(ValueError):
        eng.add_request([1, 2, 3], SamplingParams(max_tokens=2, top_p=0.0))
    # real-mode max_tokens=1 boundary: the prefill's first token is the
    # whole response — exactly one id appended past the prompt; a second
    # request overrides sampling per-request in the same batch
    prompt = synth_prompt_ids(0, 0, 9, cfg_m.vocab_size)
    h = eng.add_request(prompt, SamplingParams(max_tokens=1))
    prompt2 = synth_prompt_ids(1, 0, 9, cfg_m.vocab_size)
    h2 = eng.add_request(prompt2, SamplingParams(max_tokens=2,
                                                 temperature=0.7, top_k=8))
    outs = _drain(eng)
    assert sum(o.new_tokens for o in outs if o.handle == h) == 1
    assert len(eng._token_hist_by_conv[h]) == len(prompt) + 1
    assert sum(o.new_tokens for o in outs if o.handle == h2) == 2
    eng.shutdown()


# ---------------------------------------------------------------------------
# SLO attainment metrics
# ---------------------------------------------------------------------------


def test_slo_attainment_loose_and_tight():
    loose = _sim_engine()
    h = loose.add_request(10, SamplingParams(max_tokens=8),
                          slo=SLOSpec(ttft_ms=1e6, tbt_ms=1e6))
    _drain(loose)
    s = loose.metrics.slo_summary()
    assert s["ttft_slo_attainment"] == 1.0
    assert s["tbt_slo_attainment"] == 1.0
    assert s["slo_attainment"] == 1.0
    assert s["jain_fairness_tbt"] == 1.0
    loose.shutdown()

    tight = _sim_engine()
    tight.add_request(10, SamplingParams(max_tokens=8),
                      slo=SLOSpec(ttft_ms=1e-6, tbt_ms=1e-6))
    _drain(tight)
    s = tight.metrics.slo_summary()
    assert s["ttft_slo_attainment"] == 0.0
    assert s["tbt_slo_attainment"] == 0.0
    assert s["slo_attainment"] == 0.0
    tight.shutdown()
    # no-SLO runs report None, not garbage
    plain = _sim_engine()
    plain.add_request(10, SamplingParams(max_tokens=4))
    _drain(plain)
    s = plain.metrics.slo_summary()
    assert s["ttft_slo_attainment"] is None
    assert s["turns"] == 1
    plain.shutdown()


def test_max_tokens_one_generates_exactly_one():
    """Boundary of the SamplingParams contract: max_tokens=1 means the
    admission-time first token IS the whole response (regression: the
    decode loop over-generated by one)."""
    eng = _sim_engine()
    h = eng.add_request(8, SamplingParams(max_tokens=1))
    outs = _drain(eng)
    mine = [o for o in outs if o.handle == h]
    assert sum(o.new_tokens for o in mine) == 1
    fin = [o for o in mine if o.finished][0]
    assert fin.finish_reason == "length" and fin.generated == 1
    assert fin.first_token and fin.ttft_us is not None
    assert eng.metrics.total_tokens == 1
    eng.shutdown()


def test_recompute_chunked_mid_prefill_preempt_still_emits_first_token():
    """A sim-mode recompute preemption landing MID chunked prefill (no
    first token yet) resumes through the chunked machine — and the
    completion must still emit exactly one first token (regression: the
    resume path skipped emission unconditionally)."""
    from dataclasses import replace

    from repro.core.policies import POLICIES
    pol = replace(POLICIES["vllm-recompute"], chunked_prefill_tokens=16)
    eng = ServingEngine(
        EngineConfig(mode="sim", num_gpu_blocks=64, num_cpu_blocks=256,
                     block_size=16, max_running=8, policy=pol),
        trace=PriorityTrace("random", 1e-9, seed=0))
    h = eng.add_request(60, SamplingParams(max_tokens=7))
    eng.step()
    req = eng._req(h)
    assert req.prefill_remaining > 0 and req.first_token_us is None, \
        "scenario never caught the request mid-prefill"
    eng._preempt(h)
    assert req.resume_tokens > 0
    outs = _drain(eng)
    firsts = [o for o in outs if o.handle == h and o.first_token]
    assert len(firsts) == 1, "resume completion lost/duplicated first token"
    assert len(eng.metrics.ttfts_us) == 1
    assert sum(o.new_tokens for o in outs if o.handle == h) == 7
    eng.shutdown()


def test_handle_reuse_after_abort_gets_fresh_outputs():
    """abort(h) between steps leaves a pending terminal output; an
    immediate add_request(handle=h) must NOT inherit it (regression: the
    new request appeared aborted at birth)."""
    eng = _sim_engine()
    h = eng.add_request(8, SamplingParams(max_tokens=40))
    eng.step()
    assert eng.abort(h) is True
    h2 = eng.add_request(6, SamplingParams(max_tokens=3), handle=h)
    assert h2 == h
    outs = _drain(eng)
    mine = [o for o in outs if o.handle == h]
    assert all(o.finish_reason != "abort" for o in mine), \
        "reused handle inherited the aborted lifecycle's output"
    assert sum(o.new_tokens for o in mine) == 3
    assert [o for o in mine if o.finished][0].finish_reason == "length"
    eng.shutdown()


def test_event_log_jsonl_well_formed(tmp_path):
    from repro.launch.serve import validate_event_log
    path = tmp_path / "events.jsonl"
    lines = []
    eng = ServingEngine(
        EngineConfig(mode="sim", num_gpu_blocks=128, num_cpu_blocks=512,
                     max_running=4).with_policy("fastswitch"),
        trace=PriorityTrace("random", 1e-9, seed=0),
        event_sink=lambda ev: lines.append(json.dumps(ev.as_dict())))
    h1 = eng.add_request(6, SamplingParams(max_tokens=30), retain_kv=True)
    h2 = eng.add_request(6, SamplingParams(max_tokens=30))
    eng.step()
    assert eng.abort(h2) is True                 # cancelled mid-flight
    _drain(eng)
    eng.continue_session(h1, 4, SamplingParams(max_tokens=2))
    _drain(eng)
    path.write_text("\n".join(lines) + "\n")
    n = validate_event_log(str(path))
    assert n == len(lines)
    kinds = {json.loads(ln)["kind"] for ln in lines}
    assert {"arrive", "admit", "first_token", "finish", "continue",
            "abort"} <= kinds
    eng.shutdown()


# ---------------------------------------------------------------------------
# driver equivalence: the trace-replay client is a pure CLIENT of the
# API — a hand-rolled online loop must reproduce it exactly
# ---------------------------------------------------------------------------


def _online_replay(cfg, convs, trace, model=None, abort_at=None):
    """Hand-rolled open-world client: same protocol as
    FastSwitchEngine.run() but written against the public API only.
    ``abort_at``: optional (iteration, handle) to cancel mid-flight."""
    eng = ServingEngine(cfg, trace=trace, model_bundle=model)

    def prompt_for(conv, tix):
        t = conv.turns[tix]
        if model is None:
            return t.prompt_tokens
        return synth_prompt_ids(conv.conv_id, tix, t.prompt_tokens,
                                model["cfg"].vocab_size)

    pending = sorted(convs, key=lambda c: c.arrival_s)
    by_handle = {c.conv_id: c for c in convs}
    sleeping = []
    it = 0
    while (pending or sleeping or eng.has_work()) and it < 50_000:
        now_s = eng.clock.now_us / 1e6
        while pending and pending[0].arrival_s <= now_s:
            conv = pending.pop(0)
            t = conv.turns[0]
            eng.add_request(prompt_for(conv, 0),
                            SamplingParams(max_tokens=t.response_tokens),
                            handle=conv.conv_id,
                            retain_kv=len(conv.turns) > 1)
        for entry in list(sleeping):
            if entry[0] <= now_s:
                sleeping.remove(entry)
                _, conv, tix = entry
                t = conv.turns[tix]
                eng.continue_session(conv.conv_id, prompt_for(conv, tix),
                                     SamplingParams(
                                         max_tokens=t.response_tokens),
                                     retain_kv=tix + 1 < len(conv.turns))
        events = [w[0] * 1e6 for w in sleeping]
        if pending:
            events.append(pending[0].arrival_s * 1e6)
        outs = eng.step(until_us=min(events) if events else None)
        for out in outs:
            if out.finished and out.finish_reason == "length":
                conv = by_handle[out.handle]
                if out.turn + 1 < len(conv.turns):
                    sleeping.append((out.t_us / 1e6 + conv.think_time_s,
                                     conv, out.turn + 1))
        if abort_at is not None and it == abort_at[0]:
            eng.abort(abort_at[1])
            sleeping = [w for w in sleeping
                        if w[1].conv_id != abort_at[1]]
        it += 1
    if eng.runner is not None:
        eng.runner.flush()
    eng.swap.shutdown()
    return eng


def test_driver_equivalence_sim():
    """FastSwitchEngine's replay and an independent online client must
    produce IDENTICAL schedules — same clock, same per-token latencies,
    same swap traffic (the sim half of the ISSUE 5 parity criterion)."""
    convs = sample_conversations(15, rate_req_s=2.0, seed=3)
    cfg = EngineConfig(mode="sim", num_gpu_blocks=256, num_cpu_blocks=1024,
                       max_running=8).with_policy("fastswitch")
    a = FastSwitchEngine(cfg, [c for c in convs],
                         trace=PriorityTrace("markov", 0.04, seed=7))
    ma = a.run(max_iterations=300_000)
    assert a.done()
    b = _online_replay(cfg, [c for c in convs],
                       PriorityTrace("markov", 0.04, seed=7))
    mb = b.metrics
    assert ma.total_tokens == mb.total_tokens
    assert ma.total_time_us == mb.total_time_us
    assert ma.ttfts_us == mb.ttfts_us
    assert ma.tbts_us == mb.tbts_us
    assert ma.preemptions == mb.preemptions
    assert a.swap.stats() == b.swap.stats()


@pytest.fixture(scope="module")
def engine_model():
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("qwen2-1.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return {"cfg": cfg, "params": params}


def _storm(cid_skip=None):
    return [Conversation(conv_id=i, arrival_s=0.0,
                         turns=[Turn(16, 10), Turn(8, 6)], think_time_s=0.2)
            for i in range(4) if i != cid_skip]


def _storm_cfg():
    return EngineConfig(mode="real", num_gpu_blocks=8, num_cpu_blocks=256,
                        max_running=4, max_batch=4, block_size=16,
                        swap_chunk_blocks=1).with_policy("fastswitch")


def test_driver_equivalence_real_storm(engine_model):
    """Real-mode half of the parity criterion: under storm preemption +
    chunked swaps, the online client's greedy token streams must be
    bit-identical to the replay client's."""
    a = FastSwitchEngine(_storm_cfg(), _storm(),
                         trace=PriorityTrace("random", 0.5, seed=13),
                         model_bundle=engine_model)
    a.run(max_iterations=20_000)
    assert a.done()
    assert a.metrics.preemptions > 0
    b = _online_replay(_storm_cfg(), _storm(),
                       PriorityTrace("random", 0.5, seed=13),
                       model=engine_model)
    assert a._token_hist_by_conv == b._token_hist_by_conv
    assert a.metrics.total_tokens == b.metrics.total_tokens


def test_abort_mid_storm_leaves_survivors_bit_exact(engine_model):
    """Cancelling one conversation mid-storm must not perturb any OTHER
    conversation's greedy tokens: the survivors stay bit-identical to
    the no-abort run (cancellation releases blocks/swaps cleanly instead
    of corrupting neighbours)."""
    base = _online_replay(_storm_cfg(), _storm(),
                          PriorityTrace("random", 0.5, seed=13),
                          model=engine_model)
    ab = _online_replay(_storm_cfg(), _storm(),
                        PriorityTrace("random", 0.5, seed=13),
                        model=engine_model, abort_at=(6, 2))
    assert ab.metrics.aborted == 1
    survivors = {cid: h for cid, h in ab._token_hist_by_conv.items()
                 if cid != 2}
    assert survivors, "no survivor finished a turn"
    for cid, hist in survivors.items():
        assert hist == base._token_hist_by_conv[cid], \
            f"conv {cid} diverged after conv 2 was aborted"


def test_recompute_resume_chunked_parity(engine_model):
    """ROADMAP follow-up (ISSUE 5 satellite): the recompute-mode resume
    runs through the chunked prefill state machine — and stays
    bit-identical to the monolithic re-prefill, with exactly one first
    token per turn (a resume completion must NOT re-emit one)."""
    from dataclasses import replace

    from repro.core.policies import POLICIES

    def run(chunk):
        pol = replace(POLICIES["vllm-recompute"],
                      chunked_prefill_tokens=chunk)
        cfg = EngineConfig(mode="real", num_gpu_blocks=8,
                           num_cpu_blocks=256, max_running=4, max_batch=4,
                           block_size=16, policy=pol)
        eng = FastSwitchEngine(cfg, _storm(),
                               trace=PriorityTrace("random", 0.5, seed=13),
                               model_bundle=engine_model)
        eng.run(max_iterations=20_000)
        assert eng.done()
        return eng

    mono, chunked = run(0), run(16)
    assert mono.metrics.preemptions > 0, "storm never preempted"
    # the resumes really ran chunked (more chunk launches than prefills)
    st = chunked.runner.stats
    assert st.prefill_chunks > st.prefills, "resume never actually chunked"
    assert mono._token_hist_by_conv == chunked._token_hist_by_conv, \
        "chunked recompute-resume diverged from monolithic re-prefill"
    n_turns = sum(len(c.turns) for c in _storm())
    assert len(chunked.metrics.ttfts_us) == n_turns, \
        "resume completion re-emitted a first token"


def test_continue_session_open_world_real_streams_tokens(engine_model):
    """Open-world two-turn session with client-supplied prompt ids:
    streamed token deltas must reassemble into exactly the greedy
    straight-line reference (prefill + paged decode, no engine)."""
    from repro.cache.paged import PagedPools, PoolSpec
    from repro.models.paged import paged_decode_step, prefill_kv
    cfg_m, params = engine_model["cfg"], engine_model["params"]
    bs = 16
    turns = [(12, 6), (9, 5)]
    prompts = [synth_prompt_ids(7, i, n, cfg_m.vocab_size)
               for i, (n, _) in enumerate(turns)]

    # straight-line greedy reference
    pools = PagedPools(PoolSpec.from_config(cfg_m, 64, 64, bs))
    ref = []
    for (n_p, n_r), prompt in zip(turns, prompts):
        ref.extend(prompt)
        logits, k, v = prefill_kv(params, jnp.asarray([ref], jnp.int32),
                                  cfg=cfg_m)
        nblk = (len(ref) + bs - 1) // bs
        pools.write_tokens(list(range(nblk)), 0, np.asarray(k),
                           np.asarray(v))
        ref.append(int(np.argmax(np.asarray(logits))))
        for _ in range(n_r - 1):
            ctx = len(ref) - 1
            bt = jnp.asarray([list(range(ctx // bs + 1))], jnp.int32)
            nxt, _, pools.gpu = paged_decode_step(
                params, pools.gpu, bt, jnp.asarray([ctx], jnp.int32),
                jnp.asarray([ref[-1]], jnp.int32), cfg=cfg_m)
            ref.append(int(nxt[0]))

    cfg = EngineConfig(mode="real", num_gpu_blocks=64, num_cpu_blocks=256,
                       max_running=4, max_batch=4,
                       block_size=bs).with_policy("fastswitch")
    eng = ServingEngine(cfg, trace=PriorityTrace("random", 1e-9, seed=0),
                        model_bundle=engine_model, stream_tokens=True)
    streamed = []
    h = eng.add_request(prompts[0], SamplingParams(max_tokens=turns[0][1]),
                        handle=7, retain_kv=True)
    for out in _drain(eng):
        streamed.extend(out.token_ids or [])
    eng.continue_session(h, prompts[1],
                         SamplingParams(max_tokens=turns[1][1]))
    for out in _drain(eng):
        streamed.extend(out.token_ids or [])
    eng.shutdown()
    # the engine-side full history is bit-exact with the reference
    hist = eng._token_hist_by_conv[h]
    assert hist == ref, "open-world session diverged from reference"
    n0 = len(prompts[0])
    expect = hist[n0:n0 + turns[0][1]] \
        + hist[n0 + turns[0][1] + len(prompts[1]):]
    assert streamed == expect, "streamed deltas != generated tokens"
