"""Real-mode bucketed chunked prefill (ISSUE 4 tentpole, DESIGN.md §5).

Covers the three tentpole guarantees:
  * the position-masked chunk forward is BIT-EXACT with the monolithic
    ``prefill_kv`` for any chunking (carry layout keeps every query's
    key buffer in the monolithic masked-tail shape);
  * the DecodeRunner prefill state machine (begin / chunk / finish /
    abort) writes each KV row exactly where the block table says and
    nowhere else, across random chunk/abort interleavings (Hypothesis);
  * prompt-length variety compiles O(log max_len) prefill variants, and
    the engine emits decode tokens BETWEEN the chunks of a long prompt's
    prefill (no decode starvation, bounded per-row TBT gap).
"""
import math
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.decode_runner import DecodeRequestView, DecodeRunner
from repro.kernels import ops
from repro.models import transformer as T
from repro.models.paged import prefill_kv

BS = 16


@pytest.fixture(scope="module")
def model():
    cfg = get_smoke_config("qwen2-1.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return {"cfg": cfg, "params": params}


def _mk_pool(cfg, nb, fill=0.0):
    shape = (cfg.n_layers, 2, nb, BS, cfg.n_kv_heads, cfg.resolved_head_dim)
    return jnp.full(shape, fill, jnp.bfloat16)


def _ref(model, toks):
    """Monolithic reference: (last_logits, k, v) for the token list."""
    lg, k, v = prefill_kv(model["params"], jnp.asarray([toks], jnp.int32),
                          cfg=model["cfg"])
    return lg, k, v


# ---------------------------------------------------------------------------
# chunk forward bit-exactness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("splits", [(44,), (16, 16, 12), (32, 12),
                                    (16, 28), (5, 16, 16, 7)])
def test_prefill_chunk_bitexact_vs_monolithic(model, splits):
    """Any chunking of the prompt — including a non-aligned FIRST chunk
    (the wrapper itself has no alignment requirement; only the pool
    insert does) and ragged final chunks — reproduces the monolithic
    forward bit for bit: carry KV and last-position logits."""
    toks = np.random.RandomState(0).randint(
        1, model["cfg"].vocab_size, 44).tolist()
    lg_ref, k_ref, v_ref = _ref(model, toks)
    kc = vc = None
    pos = 0
    for n in splits:
        lg, kc, vc, _, _ = ops.prefill_chunk(
            model["params"], toks[pos:pos + n], kc, vc, pos,
            cfg=model["cfg"], block_size=BS)
        pos += n
    assert pos == len(toks)
    assert bool(jnp.all(kc[:, :pos] == k_ref))
    assert bool(jnp.all(vc[:, :pos] == v_ref))
    assert bool(jnp.all(lg == lg_ref)), "last-position logits diverged"


def test_prefill_chunk_carry_growth_is_transparent(model):
    """The pow2 carry growth between chunks never perturbs earlier KV."""
    toks = np.random.RandomState(1).randint(
        1, model["cfg"].vocab_size, 70).tolist()
    _, k_ref, v_ref = _ref(model, toks)
    kc = vc = None
    pos = 0
    buckets = []
    for n in (16, 16, 16, 16, 6):       # carry crosses 32 -> 64 -> 128
        _, kc, vc, _, _ = ops.prefill_chunk(
            model["params"], toks[pos:pos + n], kc, vc, pos,
            cfg=model["cfg"], block_size=BS)
        buckets.append(kc.shape[1])
        pos += n
    assert len(set(buckets)) > 1, "test never grew the carry"
    assert bool(jnp.all(kc[:, :pos] == k_ref))
    assert bool(jnp.all(vc[:, :pos] == v_ref))


# ---------------------------------------------------------------------------
# runner state machine: KV lands exactly where the block table says
# ---------------------------------------------------------------------------


def _check_pool_rows(model, pool, block_ids, toks, k_ref, v_ref,
                     sentinel, trash):
    """Every token's KV sits in its block-table slot; every block outside
    the table (and != trash) is untouched sentinel."""
    cfg = model["cfg"]
    bs = BS
    for t in range(len(toks)):
        blk, off = block_ids[t // bs], t % bs
        assert bool(jnp.all(pool[:, 0, blk, off] == k_ref[:, t])), f"tok {t}"
        assert bool(jnp.all(pool[:, 1, blk, off] == v_ref[:, t])), f"tok {t}"
    used = set(block_ids[:(len(toks) + bs - 1) // bs]) | {trash}
    for b in range(pool.shape[2]):
        if b not in used:
            assert bool(jnp.all(pool[:, :, b] == sentinel)), \
                f"stray write into block {b}"


def test_runner_chunked_state_machine_matches_monolithic(model):
    """begin/chunk/chunk/finish: pool rows == monolithic KV, first token
    == greedy argmax of the last-position logits, no stray writes."""
    cfg = model["cfg"]
    nb, trash, sentinel = 12, 11, 3.0
    pool = _mk_pool(cfg, nb, fill=sentinel)
    runner = DecodeRunner(model, block_size=BS, trash_block=trash)
    toks = np.random.RandomState(2).randint(1, cfg.vocab_size, 40).tolist()
    lg_ref, k_ref, v_ref = _ref(model, toks)
    hist = list(toks)
    block_ids = [5, 2, 7]                        # deliberately non-identity
    view = DecodeRequestView(0, block_ids, hist)
    total = runner.prefill_begin(view, emit_first=True)
    assert total == 40
    for n in (16, 16, 8):
        staged = runner.prefill_chunk_compute(0, n)
        pool = runner.prefill_chunk_insert(0, pool, staged)
    runner.prefill_finish(0)
    assert hist[-1] == int(jnp.argmax(lg_ref))
    assert runner._prefills == {}
    _check_pool_rows(model, pool, block_ids, toks, k_ref, v_ref,
                     sentinel, trash)


def test_runner_prefill_abort_and_restart(model):
    """Aborting mid-prefill drops the carry; a fresh begin reprocesses
    from scratch and converges to the same pool content and first token."""
    cfg = model["cfg"]
    nb, trash, sentinel = 10, 9, 3.0
    pool = _mk_pool(cfg, nb, fill=sentinel)
    runner = DecodeRunner(model, block_size=BS, trash_block=trash)
    toks = np.random.RandomState(3).randint(1, cfg.vocab_size, 33).tolist()
    lg_ref, k_ref, v_ref = _ref(model, toks)
    hist = list(toks)
    view = DecodeRequestView(0, [0, 1, 2], hist)
    runner.prefill_begin(view, emit_first=True)
    staged = runner.prefill_chunk_compute(0, 16)
    pool = runner.prefill_chunk_insert(0, pool, staged)
    runner.prefill_abort(0)
    assert runner.stats.prefill_aborts == 1
    assert len(hist) == 33                       # no token emitted
    # restart from scratch
    runner.prefill_begin(view, emit_first=True)
    while (n := min(16, runner.prefill_pending(0))) > 0:
        staged = runner.prefill_chunk_compute(0, n)
        pool = runner.prefill_chunk_insert(0, pool, staged)
    runner.prefill_finish(0)
    assert hist[-1] == int(jnp.argmax(lg_ref))
    _check_pool_rows(model, pool, [0, 1, 2], toks, k_ref, v_ref,
                     sentinel, trash)


def test_chunked_prefill_property_random_interleavings(model):
    """Hypothesis property (ISSUE 4 satellite): random chunk sizes and
    abort/restart points never lose or double-write KV rows — the final
    pool holds exactly the monolithic KV in the request's blocks, every
    other block keeps its sentinel, and the state machine ends empty."""
    pytest.importorskip("hypothesis",
                        reason="dev-only dep; see requirements-dev.txt")
    from hypothesis import given, settings, strategies as st

    cfg = model["cfg"]
    nb, trash, sentinel = 10, 9, 3.0
    refs = {}

    @settings(max_examples=8, deadline=None)
    @given(st.data())
    def run(data):
        total = data.draw(st.integers(4, 72), label="total")
        toks = np.random.RandomState(total).randint(
            1, cfg.vocab_size, total).tolist()
        if total not in refs:
            refs[total] = _ref(model, toks)
        lg_ref, k_ref, v_ref = refs[total]
        pool = _mk_pool(cfg, nb, fill=sentinel)
        runner = DecodeRunner(model, block_size=BS, trash_block=trash)
        hist = list(toks)
        view = DecodeRequestView(0, [4, 1, 6, 2, 7], hist)
        runner.prefill_begin(view, emit_first=True)
        aborts = 0
        while (rem := runner.prefill_pending(0)) > 0:
            # mirror the engine's chunk rounding: non-final chunks are
            # block-size multiples
            n = min(data.draw(st.integers(1, 48), label="chunk"), rem)
            if n < rem:
                n -= n % BS
                if n == 0:
                    n = min(BS, rem)
            staged = runner.prefill_chunk_compute(0, n)
            pool = runner.prefill_chunk_insert(0, pool, staged)
            if (aborts < 2 and runner.prefill_pending(0) > 0
                    and data.draw(st.integers(0, 3), label="abort") == 0):
                runner.prefill_abort(0)
                aborts += 1
                runner.prefill_begin(view, emit_first=True)
        runner.prefill_finish(0)
        assert hist[-1] == int(jnp.argmax(lg_ref))
        assert runner._prefills == {}
        _check_pool_rows(model, pool, [4, 1, 6, 2, 7], toks, k_ref, v_ref,
                         sentinel, trash)

    run()


def test_seeded_carry_resumes_from_pool_prefix(model):
    """Re-admission with a reused prefix: ``prefill_begin`` seeds the
    carry from KV already resident in the pool and processes ONLY the
    tail beyond the block-aligned reused prefix — final pool content and
    first token stay bit-exact with the monolithic forward."""
    cfg = model["cfg"]
    nb, trash, sentinel = 10, 9, 3.0
    pool = _mk_pool(cfg, nb, fill=sentinel)
    runner = DecodeRunner(model, block_size=BS, trash_block=trash)
    toks = np.random.RandomState(4).randint(1, cfg.vocab_size, 48).tolist()
    lg_ref, k_ref, v_ref = _ref(model, toks)
    block_ids = [3, 0, 5]
    # simulate the reuse swap-in: the prefix KV (first 2 pages) is
    # already resident in the pool
    pool = ops.insert_prefill(pool, k_ref[:, :32], v_ref[:, :32],
                              block_ids[:2], BS)
    hist = list(toks)
    view = DecodeRequestView(0, block_ids, hist)
    total = runner.prefill_begin(view, emit_first=True, reused_tokens=35,
                                 pool=pool)
    assert total == 48 - 32            # 35 rounds down to the page floor
    assert runner.prefill_pending(0) == 16
    staged = runner.prefill_chunk_compute(0, 16)
    pool = runner.prefill_chunk_insert(0, pool, staged)
    runner.prefill_finish(0)
    assert hist[-1] == int(jnp.argmax(lg_ref))
    _check_pool_rows(model, pool, block_ids, toks, k_ref, v_ref,
                     sentinel, trash)


# ---------------------------------------------------------------------------
# jit-cache bound: prompt-length sweep
# ---------------------------------------------------------------------------


def test_prefill_jit_cache_bounded_over_prompt_sweep(model):
    """ISSUE 4 acceptance: 40 distinct prompt lengths through the
    runner's (now bucketed) prefill compile O(log max_len) chunk-forward
    variants — the legacy exact-shape ``prefill_kv`` compiled one per
    length."""
    cfg = model["cfg"]
    max_len = 200
    nb = max_len // BS + 3
    runner = DecodeRunner(model, block_size=BS, trash_block=nb - 1)
    pool = _mk_pool(cfg, nb)
    rng = np.random.RandomState(0)
    lens = rng.choice(np.arange(3, max_len), size=40, replace=False)
    c0 = ops.prefill_chunk_cache_size()
    for n in lens:
        hist = rng.randint(1, cfg.vocab_size, int(n)).tolist()
        view = DecodeRequestView(0, list(range(len(hist) // BS + 1)), hist)
        pool = runner.prefill(view, pool, emit_first=True)
    grew = ops.prefill_chunk_cache_size() - c0
    bound = math.ceil(math.log2(max_len)) + 1
    assert grew <= bound, \
        f"{grew} compiled prefill variants for 40 lengths (bound {bound})"


# ---------------------------------------------------------------------------
# engine interleaving: no decode starvation during a long prefill
# ---------------------------------------------------------------------------


def _interleave_engine(model, chunked, prompt_tokens, chunk=64):
    from repro.core import EngineConfig, FastSwitchEngine
    from repro.core.policies import POLICIES
    from repro.data.priority import PriorityTrace
    from repro.data.sharegpt import Conversation, Turn
    # small block groups: the default 60-block groups would eat the tiny
    # pool after two admissions and serialize the whole scenario
    pol = replace(POLICIES["fastswitch"], initial_group_blocks=4)
    if chunked:
        pol = replace(pol, chunked_prefill_tokens=chunk)
    convs = [Conversation(conv_id=i, arrival_s=0.0,
                          turns=[Turn(8, 40)], think_time_s=0.1)
             for i in range(4)]
    # arrival 0.0: all five admit in the cold first iteration (no batch
    # bucket compiled yet -> no admission hold), so the decode batch and
    # the long prefill genuinely overlap
    convs.append(Conversation(conv_id=4, arrival_s=0.0,
                              turns=[Turn(prompt_tokens, 3)],
                              think_time_s=0.1))
    cfg = EngineConfig(mode="real",
                       num_gpu_blocks=prompt_tokens // 16 + 24,
                       num_cpu_blocks=512, max_running=8, max_batch=8,
                       block_size=16, policy=pol)
    return FastSwitchEngine(cfg, convs, trace=PriorityTrace(),
                            model_bundle=model)


def test_chunked_prefill_interleaves_decode_with_bounded_tbt(model):
    """ISSUE 4 satellite: with a 4-row decode batch and a long-prompt
    admission, decode tokens ARE emitted between the prompt's chunks and
    every row keeps emitting in (nearly) every chunk iteration — the
    per-row TBT gap is bounded at ~1 iteration, i.e. no decode
    starvation while the 512-token prompt prefills."""
    prompt = 512
    eng = _interleave_engine(model, chunked=True, prompt_tokens=prompt)
    reqs = {}
    per_row = {r: 0 for r in range(4)}
    chunk_iters = 0
    for _ in range(5000):
        if eng.done():
            break
        before = {r: req.generated for r, req in eng.sched.requests.items()
                  if r < 4}
        reqs.update(eng.sched.requests)
        eng.step()
        long_req = reqs.get(4)
        if long_req is not None and long_req.prefill_remaining > 0:
            chunk_iters += 1
            for r, req in eng.sched.requests.items():
                if r < 4:
                    per_row[r] += req.generated - before.get(r, req.generated)
    assert eng.done()
    # the admission iteration itself is not counted (the request enters
    # ``reqs`` post-step), hence the -2
    assert chunk_iters >= prompt // 64 - 2, "prefill never chunked"
    for r, emitted in per_row.items():
        assert emitted >= chunk_iters - 1, \
            f"row {r} starved: {emitted} tokens over {chunk_iters} " \
            f"chunk iterations (TBT gap > 2 iterations)"


def test_monolithic_prefill_has_no_interleave_window(model):
    """Contrast baseline: the monolithic real-mode path completes the
    whole 512-token prefill inside the admission iteration —
    ``prefill_remaining`` is never observable, so zero decode tokens can
    interleave with the prompt processing."""
    prompt = 512
    eng = _interleave_engine(model, chunked=False, prompt_tokens=prompt)
    reqs = {}
    window = 0
    for _ in range(5000):
        if eng.done():
            break
        reqs.update(eng.sched.requests)
        eng.step()
        for req in eng.sched.requests.values():
            window += req.prefill_remaining > 0
    assert eng.done()
    assert 4 in reqs and reqs[4].generated == 3     # the long conv ran
    assert window == 0, "monolithic prefill unexpectedly chunked"
