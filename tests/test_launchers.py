"""Integration tests: the CLI launchers and checkpointing round-trips."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
ENV = {**os.environ, "PYTHONPATH": SRC}


def _run(args, timeout=300):
    return subprocess.run([sys.executable, *args], env=ENV, cwd=SRC + "/..",
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_dryrun_cli_single_case():
    r = _run(["-m", "repro.launch.dryrun", "--arch", "qwen2-1.5b",
              "--shape", "decode_32k"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK   qwen2-1.5b x decode_32k" in r.stdout


@pytest.mark.slow
def test_serve_cli_sim():
    r = _run(["-m", "repro.launch.serve", "--policy", "fastswitch",
              "--conversations", "20", "--gpu-blocks", "512"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "fastswitch" in r.stdout


@pytest.mark.slow
def test_train_cli():
    r = _run(["-m", "repro.launch.train", "--arch", "qwen2-1.5b",
              "--steps", "3", "--batch", "2", "--seq", "32"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "step" in r.stdout


def test_checkpoint_roundtrip(tmp_path):
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.train.checkpoint import load_checkpoint, save_checkpoint
    cfg = get_smoke_config("llama3.2-3b")
    params = T.init_params(cfg, jax.random.PRNGKey(3))
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, params)
    restored = load_checkpoint(path, params)
    flat1 = jax.tree.leaves(params)
    flat2 = jax.tree.leaves(restored)
    assert len(flat1) == len(flat2)
    for a, b in zip(flat1, flat2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_optimizer_state(tmp_path):
    from repro.configs import get_smoke_config
    from repro.models import steps, transformer as T
    from repro.train.checkpoint import load_checkpoint, save_checkpoint
    from repro.train.optimizer import adamw_init
    cfg = get_smoke_config("qwen2-1.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab_size)
    params, opt, _ = steps.train_step(params, opt,
                                      {"tokens": tokens, "labels": tokens},
                                      cfg=cfg)
    path = str(tmp_path / "opt.npz")
    save_checkpoint(path, opt)
    restored = load_checkpoint(path, opt)
    assert int(restored.step) == 1
    np.testing.assert_array_equal(
        np.asarray(jax.tree.leaves(opt.mu)[0]),
        np.asarray(jax.tree.leaves(restored.mu)[0]))
