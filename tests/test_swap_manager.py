"""Multithreading Swap Manager — Algorithm 1 semantics + timing model."""
from repro.core.swap_manager import MultithreadingSwapManager, SimClock
from repro.io.cost_model import TPU_V5E_HOST, dispatch_time_us, exec_time_us


def _mgr(**kw):
    return MultithreadingSwapManager(TPU_V5E_HOST, None, **kw)


BB = 128 * 1024   # block bytes


def test_sync_dispatch_stalls_clock():
    m = _mgr(async_enabled=False)
    clock = SimClock()
    runs = [(0, 1)] * 10
    m.dispatch(clock, 1, "out", runs, BB, range(10), asynchronous=False)
    expect = 10 * dispatch_time_us(TPU_V5E_HOST) + \
        10 * exec_time_us(TPU_V5E_HOST, BB, h2d=False)
    assert clock.now_us >= expect
    assert m.total_stall_us >= expect


def test_async_dispatch_does_not_stall():
    m = _mgr()
    clock = SimClock()
    t = m.dispatch(clock, 1, "in", [(0, 10)], BB, range(10),
                   asynchronous=True)
    assert clock.now_us == 0.0
    assert t.done_at > 0
    assert m.ongoing_swap_in == [t]
    # not completed before its done_at
    assert m.poll_completed(clock) == []
    clock.advance_to(t.done_at)
    assert m.poll_completed(clock) == [t]
    assert m.ongoing_swap_in == []


def test_grouped_fewer_ops_is_faster():
    hw = TPU_V5E_HOST
    m1, m2 = _mgr(), _mgr()
    c1, c2 = SimClock(), SimClock()
    # same 64 blocks: per-block vs one run
    m1.dispatch(c1, 1, "out", [(i, 1) for i in range(64)], BB, range(64),
                asynchronous=False)
    m2.dispatch(c2, 1, "out", [(0, 64)], BB, range(64), asynchronous=False)
    assert c2.now_us < c1.now_us
    # dispatch overhead dominates the per-block path
    assert c1.now_us - c2.now_us > 0.5 * 63 * dispatch_time_us(hw)


def test_conflict_detection_and_sync():
    m = _mgr()
    clock = SimClock()
    t = m.dispatch(clock, 1, "in", [(5, 10)], BB, range(5, 15),
                   asynchronous=True)
    assert m.detect_conflicts([20, 21]) == []
    assert m.detect_conflicts([14]) == [t]
    n = m.resolve_conflicts(clock, [14, 99])
    assert n == 1
    assert clock.now_us >= t.done_at        # synchronized
    assert m.ongoing_swap_in == []
    assert m.n_conflicts == 1


def test_stream_serialization():
    """Two async swaps share the I/O stream: the second queues behind."""
    m = _mgr()
    clock = SimClock()
    t1 = m.dispatch(clock, 1, "in", [(0, 32)], BB, range(32),
                    asynchronous=True)
    t2 = m.dispatch(clock, 2, "in", [(32, 32)], BB, range(32, 64),
                    asynchronous=True)
    assert t2.done_at > t1.done_at
    assert t2.done_at - t1.done_at >= exec_time_us(
        TPU_V5E_HOST, 32 * BB, h2d=True) * 0.9


def test_adaptive_decision():
    m = _mgr(adaptive=True)
    clock = SimClock()
    # seed r_info with small swaps
    for i in range(20):
        m.dispatch(clock, i, "out", [(i, 1)], BB, [i], asynchronous=True)
    # small pending swap + big batch -> sync preferred
    assert m.decide_async(running_batch=64, pending_swap_blocks=1) is False
    # large pending swap -> async
    assert m.decide_async(running_batch=64, pending_swap_blocks=100) is True
    # async disabled entirely
    m2 = _mgr(async_enabled=False)
    assert m2.decide_async(1, 1000) is False


def test_r_info_records_issue_time():
    """SwapRecord.t_us must be the ISSUE time: a synchronous dispatch
    stalls the clock to done_at before the record is appended, and the
    adaptive profiler needs issue-time ordering."""
    m = _mgr(async_enabled=False)
    clock = SimClock()
    t = m.dispatch(clock, 1, "out", [(0, 8)], BB, range(8),
                   asynchronous=False)
    assert clock.now_us >= t.done_at           # sync stall happened
    assert m.r_info[-1].t_us == t.issued_at == 0.0
    # a later swap records its own (post-stall) issue time
    t2 = m.dispatch(clock, 2, "out", [(8, 8)], BB, range(8, 16),
                    asynchronous=False)
    assert m.r_info[-1].t_us == t2.issued_at == t.done_at


def test_r_info_window_bounded():
    m = _mgr(r_info_window=8)
    clock = SimClock()
    for i in range(30):
        m.dispatch(clock, i, "out", [(0, 1)], BB, [0], asynchronous=True)
    assert len(m.r_info) <= 8
