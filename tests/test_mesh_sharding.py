"""Mesh-sharded serving (ISSUE 8): tensor-parallel KV pool + per-shard
staged swap plane must be BIT-IDENTICAL to the single-device engine.

The multi-device tests run in subprocesses because
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` must be set
before the first jax import (tests/conftest.py deliberately keeps the
main pytest process at 1 device — smoke tests and benches depend on
that).  Each subprocess runs BOTH mesh shapes so the comparison shares
one process's params/schedule exactly.
"""
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_forced(code, n_devices=4, timeout=900):
    env = {**os.environ, "PYTHONPATH": SRC,
           "XLA_FLAGS":
           f"--xla_force_host_platform_device_count={n_devices}"}
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    return r.stdout


# ---------------------------------------------------------------------------
# mesh plumbing (single-device process)
# ---------------------------------------------------------------------------


def test_make_serving_mesh_identity_and_device_check():
    from repro.launch.mesh import make_serving_mesh
    assert make_serving_mesh((1, 1)) is None
    with pytest.raises(ValueError, match="devices"):
        make_serving_mesh((1, 64))


def test_sim_engine_ignores_mesh_shape():
    """Sim mode has no device data plane: mesh_shape must be accepted
    and produce byte-identical simulated runs."""
    from repro.core import EngineConfig, FastSwitchEngine
    from repro.data.priority import PriorityTrace
    from repro.data.sharegpt import Conversation, Turn

    def run(shape):
        convs = [Conversation(conv_id=i, arrival_s=0.05 * i,
                              turns=[Turn(40, 30), Turn(20, 20)],
                              think_time_s=0.3) for i in range(6)]
        cfg = EngineConfig(mode="sim", num_gpu_blocks=32,
                           num_cpu_blocks=256, max_running=3,
                           swap_chunk_blocks=2,
                           mesh_shape=shape).with_policy("fastswitch")
        eng = FastSwitchEngine(cfg, convs,
                               trace=PriorityTrace("random", 0.5, seed=5))
        eng.run(max_iterations=50_000)
        assert eng.done()
        # drop host wall-clock keys — everything simulated must match
        return {k: v for k, v in eng.metrics.summary().items()
                if "wall" not in k}

    assert run((1, 1)) == run((1, 4))


def test_shard_local_config_divides_heads():
    from repro.models.paged import shard_local_config, shardable_heads
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("llama3.2-3b")   # 4 q / 2 kv smoke heads
    assert shardable_heads(cfg, 1) and shardable_heads(cfg, 2)
    assert not shardable_heads(cfg, 4)      # 2 kv heads can't split 4-way
    loc = shard_local_config(cfg, 2)
    assert loc.n_heads == cfg.n_heads // 2
    assert loc.n_kv_heads == cfg.n_kv_heads // 2
    assert loc.resolved_head_dim == cfg.resolved_head_dim


# ---------------------------------------------------------------------------
# real-mode engine: 4-way mesh bit-parity under storm preemption + swap
# (ISSUE 8 acceptance) + per-shard transfer accounting + jit-cache bound
# ---------------------------------------------------------------------------

ENGINE_PARITY = """
import dataclasses
import jax
import numpy as np
from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.core import EngineConfig, FastSwitchEngine
from repro.core.decode_runner import DecodeRunner
from repro.data.priority import PriorityTrace
from repro.data.sharegpt import Conversation, Turn

assert len(jax.devices()) == 4, jax.devices()
# uniform 4-head config so the model axis can split 4 ways
cfg_m = dataclasses.replace(get_smoke_config("llama3.2-3b"),
                            n_heads=4, n_kv_heads=4, head_dim=16,
                            d_model=64, n_layers=2, d_ff=128,
                            vocab_size=256)
mb = {"cfg": cfg_m, "params": T.init_params(cfg_m, jax.random.PRNGKey(0))}

def mk():
    return [Conversation(conv_id=i, arrival_s=0.0,
                         turns=[Turn(16, 12), Turn(8, 8)],
                         think_time_s=0.2) for i in range(4)]

def run(shape):
    cfg = EngineConfig(mode="real", num_gpu_blocks=8, num_cpu_blocks=256,
                       max_running=4, max_batch=4, block_size=16,
                       swap_chunk_blocks=1,
                       mesh_shape=shape).with_policy("fastswitch")
    eng = FastSwitchEngine(cfg, mk(),
                           trace=PriorityTrace("random", 0.5, seed=13),
                           model_bundle=mb)
    eng.run(max_iterations=20_000)
    assert eng.done()
    assert eng.metrics.preemptions > 0, "schedule never preempted"
    assert eng.metrics.swap_in_count > 0, "schedule never swapped in"
    return {c: list(h) for c, h in eng._token_hist_by_conv.items()}, eng

c0 = DecodeRunner.jit_cache_size()
h1, e1 = run((1, 1))
h4, e4 = run((1, 4))
assert h1 == h4, "mesh (1,4) token histories diverge from single-device"
# staged swap plane: EXACTLY one host transfer per chunk per shard
assert e4.pools.n_shards == 4
assert e4.pools.staged_out_calls > 0 and e4.pools.staged_in_calls > 0
assert e4.pools.d2h_transfers == 4 * e4.pools.staged_out_calls, (
    e4.pools.d2h_transfers, e4.pools.staged_out_calls)
assert e4.pools.h2d_transfers == 4 * e4.pools.staged_in_calls, (
    e4.pools.h2d_transfers, e4.pools.staged_in_calls)
assert e1.pools.n_shards == 1
assert e1.pools.d2h_transfers == e1.pools.staged_out_calls
# jit-variant budget (fslint FS002 discipline): the whole storm run —
# BOTH mesh shapes, every batch/chunk bucket — stays within the
# pow2-bucketed variant bound (4 batch buckets per variant family)
compiles = DecodeRunner.jit_cache_size() - c0
assert compiles <= 8, f"decode-step variants exploded: {compiles}"
print("ENGINE_PARITY_OK", sum(len(v) for v in h1.values()), compiles)
"""


def test_real_engine_4way_mesh_bit_parity_under_storm():
    out = _run_forced(ENGINE_PARITY)
    assert "ENGINE_PARITY_OK" in out


# ---------------------------------------------------------------------------
# vocab-sharded unembed: greedy candidate gather + sampled-row fallback
# must be bit-exact with the single-device full-logits step
# ---------------------------------------------------------------------------

SAMPLED_UNEMBED_PARITY = """
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.models.paged import (paged_decode_step_device,
                                paged_decode_step_device_sharded)

assert len(jax.devices()) == 4
cfg = dataclasses.replace(get_smoke_config("llama3.2-3b"),
                          n_heads=4, n_kv_heads=4, head_dim=16,
                          d_model=64, n_layers=2, d_ff=128,
                          vocab_size=256)
params = T.init_params(cfg, jax.random.PRNGKey(0))
mesh = jax.make_mesh((1, 4), ("data", "model"))

B, n_pages, bs = 4, 4, 16
rng = np.random.RandomState(3)
pool_shape = (cfg.n_layers, 2, B * n_pages + 1, bs, cfg.n_kv_heads,
              cfg.head_dim)
pool0 = rng.randn(*pool_shape).astype(np.float32)
tables = np.arange(B * n_pages, dtype=np.int32).reshape(B, n_pages)
ctx = np.array([5, 17, 30, 47], np.int32)
toks = rng.randint(0, cfg.vocab_size, size=(B,)).astype(np.int32)
active = np.ones((B,), bool)
keys = jnp.stack([jax.random.PRNGKey(100 + i) for i in range(B)])

def run(fn, sampling, **kw):
    nxt, _, new_ctx, new_tok = fn(
        params, jnp.asarray(pool0), jnp.asarray(tables),
        jnp.asarray(ctx), jnp.asarray(toks), jnp.asarray(active), keys,
        jnp.asarray(sampling, jnp.float32), cfg=cfg, **kw)
    return np.asarray(nxt), np.asarray(new_ctx), np.asarray(new_tok)

greedy = np.zeros((B, 3), np.float32); greedy[:, 2] = 1.0
mixed = greedy.copy()
mixed[1] = (0.8, 5, 0.9)      # top-k + nucleus sampled row
mixed[3] = (1.3, 0, 0.7)      # nucleus-only sampled row

for sampling in (greedy, mixed):
    a = run(paged_decode_step_device, sampling)
    b = run(paged_decode_step_device_sharded, sampling, mesh=mesh)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)
print("SAMPLED_UNEMBED_OK")
"""


def test_vocab_sharded_unembed_greedy_and_sampled_parity():
    out = _run_forced(SAMPLED_UNEMBED_PARITY)
    assert "SAMPLED_UNEMBED_OK" in out


# ---------------------------------------------------------------------------
# per-shard staged slab round trip (bit-exact, incl. partial last block)
# ---------------------------------------------------------------------------

SLAB_ROUND_TRIP = """
import dataclasses
import jax
import jax.numpy as jnp
import numpy as np
from repro.cache.paged import PagedPools, PoolSpec

assert len(jax.devices()) == 4
spec = PoolSpec(n_layers=2, n_kv_heads=4, head_dim=16, block_size=16,
                num_gpu_blocks=12, num_cpu_blocks=24)
mesh = jax.make_mesh((1, 4), ("data", "model"))

def fill(pools, seed):
    rng = np.random.RandomState(seed)
    full = rng.randn(*pools.gpu.shape).astype(np.float32)
    pools.gpu = jax.device_put(
        jnp.asarray(full, pools.gpu.dtype), pools.gpu.sharding)

for shape, n_shards in ((None, 1), (mesh, 4)):
    pools = PagedPools(spec, mesh=shape)
    assert pools.n_shards == n_shards
    fill(pools, 7)
    before = np.asarray(pools.gpu).copy()
    # swap out 5 blocks as 2 chunks — the 2nd is a PARTIAL last chunk
    # (3 blocks into a 4-block slab bucket)
    pools.copy_out_staged([(1, 2)], [0, 1])
    pools.copy_out_staged([(4, 3)], [2, 3, 4])
    # clobber exactly the swapped-out gpu blocks, then stage back in
    for lo, hi in ((1, 3), (4, 7)):
        z = jnp.zeros_like(pools.gpu[:, :, lo:hi])
        pools.gpu = pools.gpu.at[:, :, lo:hi].set(z)
    pools.copy_in_staged([0, 1], [(1, 2)])
    pools.copy_in_staged([2, 3, 4], [(4, 3)])
    after = np.asarray(pools.gpu)
    np.testing.assert_array_equal(before, after)
    assert pools.d2h_transfers == n_shards * 2, pools.d2h_transfers
    assert pools.h2d_transfers == n_shards * 2, pools.h2d_transfers
    # sharded pool really is head-sharded over the mesh
    if shape is not None:
        assert len(pools.gpu.sharding.device_set) == 4

# cross-mode: slab staged OUT on the mesh, read back on host, must
# equal the single-device bytes (layout is shard-transparent)
p1 = PagedPools(spec, mesh=None)
p4 = PagedPools(spec, mesh=mesh)
fill(p1, 11)
fill(p4, 11)
for p in (p1, p4):
    p.copy_out_staged([(2, 3)], [5, 6, 7])
np.testing.assert_array_equal(p1.cpu[:, :, 5:8], p4.cpu[:, :, 5:8])
print("SLAB_ROUND_TRIP_OK")
"""


def test_per_shard_slab_round_trip_bit_exact():
    out = _run_forced(SLAB_ROUND_TRIP)
    assert "SLAB_ROUND_TRIP_OK" in out
