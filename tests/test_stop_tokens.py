"""EOS stop tokens (``SamplingParams.stop_token_ids``).

Contract under test (DESIGN.md §6 finish semantics):
  * real mode: a decoded token matching the stop set ends the turn with
    ``finish_reason="stop"`` (vs ``"length"`` at the max_tokens budget);
    the stop token itself STAYS in the streamed delta and the token
    history — truncation is presentation, the bit-exact history is the
    engine's parity anchor, so the pre-stop stream must be a prefix of
    the unconstrained greedy stream;
  * the first decoded token can itself be the stop token (the
    prefill-emission path, not the batch-decode path, must check);
  * a stop hit exactly at the max_tokens boundary reports ``"stop"``,
    not ``"length"`` (the more informative reason wins);
  * sim mode carries no token ids: stop sets are accepted but can
    never fire — a sim request always runs to its length budget.
"""
import jax
import pytest

from repro.core import EngineConfig, SamplingParams, ServingEngine
from repro.data.priority import PriorityTrace
from repro.data.sharegpt import synth_prompt_ids


@pytest.fixture(scope="module")
def engine_model():
    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    cfg = get_smoke_config("qwen2-1.5b")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return {"cfg": cfg, "params": params}


@pytest.fixture(scope="module", autouse=True)
def _release_jit_state():
    # real-engine variants compiled here stress the global jax-cpu jit
    # state; release it so later modules' native compiles stay safe
    # (the test_system segfault family)
    yield
    jax.clear_caches()


def _real_cfg():
    return EngineConfig(mode="real", num_gpu_blocks=32, num_cpu_blocks=128,
                        max_running=4, max_batch=4).with_policy("fastswitch")


def _drain(eng, max_iters=20_000):
    outs = []
    it = 0
    while eng.has_work() and it < max_iters:
        outs.extend(eng.step())
        it += 1
    assert not eng.has_work()
    return outs


def _run_real(model, prompt_ids, max_tokens, stop=()):
    eng = ServingEngine(_real_cfg(), model_bundle=model, stream_tokens=True)
    eng.add_request(prompt_ids,
                    SamplingParams(max_tokens=max_tokens,
                                   stop_token_ids=tuple(stop)))
    outs = _drain(eng)
    toks = [t for o in outs if o.token_ids for t in o.token_ids]
    fin = [o for o in outs if o.finished]
    assert len(fin) == 1
    return toks, fin[0]


def test_sim_stop_ids_accepted_but_never_fire():
    eng = ServingEngine(
        EngineConfig(mode="sim", num_gpu_blocks=64, num_cpu_blocks=256,
                     max_running=4).with_policy("fastswitch"),
        trace=PriorityTrace("random", 1e-9, seed=0))
    eng.add_request(24, SamplingParams(max_tokens=10, stop_token_ids=(3, 5)))
    outs = _drain(eng)
    fin = [o for o in outs if o.finished]
    assert len(fin) == 1
    assert fin[0].finish_reason == "length"
    assert fin[0].generated == 10


def test_real_stop_mid_stream_prefix_exact(engine_model):
    vocab = engine_model["cfg"].vocab_size
    prompt = synth_prompt_ids(11, 0, 16, vocab)
    hist, fin = _run_real(engine_model, prompt, 12)
    assert fin.finish_reason == "length" and len(hist) == 12

    stop_tok = hist[7]
    cut = hist.index(stop_tok)           # earliest hit wins
    toks, fin2 = _run_real(engine_model, prompt, 12, stop=(stop_tok,))
    assert fin2.finish_reason == "stop"
    # the stop token stays in the stream; everything before it is the
    # unconstrained greedy prefix, bit-exact
    assert toks == hist[:cut + 1]
    assert fin2.generated == cut + 1


def test_real_stop_on_first_token(engine_model):
    vocab = engine_model["cfg"].vocab_size
    prompt = synth_prompt_ids(12, 0, 16, vocab)
    hist, _ = _run_real(engine_model, prompt, 8)
    toks, fin = _run_real(engine_model, prompt, 8, stop=(hist[0],))
    assert fin.finish_reason == "stop"
    assert toks == hist[:1]
    assert fin.generated == 1


def test_real_stop_at_length_boundary_upgrades_reason(engine_model):
    vocab = engine_model["cfg"].vocab_size
    prompt = synth_prompt_ids(13, 0, 16, vocab)
    hist, _ = _run_real(engine_model, prompt, 10)
    stop_tok = hist[-1]
    cut = hist.index(stop_tok)
    toks, fin = _run_real(engine_model, prompt, 10, stop=(stop_tok,))
    # even when the stop lands on the final budgeted token, the reason
    # reports the stop (the earliest occurrence in the stream decides)
    assert fin.finish_reason == "stop"
    assert toks == hist[:cut + 1]


def test_real_stop_with_retained_session_parks(engine_model):
    """A stop-finished turn with ``retain_kv`` parks like a length
    finish — follow-ups continue from the truncated history."""
    vocab = engine_model["cfg"].vocab_size
    prompt = synth_prompt_ids(14, 0, 16, vocab)
    hist, _ = _run_real(engine_model, prompt, 8)
    stop_tok = hist[3]
    cut = hist.index(stop_tok)

    eng = ServingEngine(_real_cfg(), model_bundle=engine_model,
                        stream_tokens=True)
    h = eng.add_request(prompt, SamplingParams(max_tokens=8,
                                               stop_token_ids=(stop_tok,)),
                        retain_kv=True)
    outs = _drain(eng)
    fin = [o for o in outs if o.finished]
    assert fin[0].finish_reason == "stop"
    assert h in eng.parked
    assert eng.parked[h].token_history == list(prompt) + hist[:cut + 1]
    eng.continue_session(h, synth_prompt_ids(14, 1, 8, vocab),
                         SamplingParams(max_tokens=4))
    outs2 = _drain(eng)
    fin2 = [o for o in outs2 if o.finished]
    assert fin2[0].finish_reason == "length"
